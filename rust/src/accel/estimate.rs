//! Whole-system latency/energy estimation for partitioned execution — the
//! model behind the Table I "DPU+VPU" row and the AB-P cut-point sweep.

use std::collections::BTreeMap;

use crate::accel::interconnect::Link;
use crate::accel::traits::{network_latency, Accelerator, NetworkLatency};
use crate::net::compiler::partition::{Partition, PartitionError};
use crate::net::graph::Graph;
use crate::net::layers::Op;

/// Estimation failure: the partition references something the model set
/// does not cover.  A `Result` (not a panic) so a bad `--partition` flag
/// surfaces as a CLI error instead of aborting the serve loop.
#[derive(Debug)]
pub enum EstimateError {
    /// A stage names an accelerator absent from the model map.
    UnknownAccelerator { name: String, layer: String },
    /// The partition itself is malformed (non-contiguous, bad covering).
    BadPartition(PartitionError),
}

impl std::fmt::Display for EstimateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EstimateError::UnknownAccelerator { name, layer } => {
                write!(f, "partition assigns layer {layer} to unknown accelerator {name:?}")
            }
            EstimateError::BadPartition(e) => write!(f, "bad partition: {e}"),
        }
    }
}

impl std::error::Error for EstimateError {}

impl From<PartitionError> for EstimateError {
    fn from(e: PartitionError) -> EstimateError {
        EstimateError::BadPartition(e)
    }
}

/// Per-stage latency of a contiguous pipeline partition.
#[derive(Debug, Clone)]
pub struct StageLatency {
    /// Accelerator executing the stage.
    pub accel: String,
    /// Layer ids of the stage (topological order).
    pub layers: Vec<usize>,
    /// Device busy seconds (sum of the stage's layer costs).
    pub busy_s: f64,
    /// Boundary transfer seconds for every edge leaving the stage
    /// (0 for the last stage).
    pub transfer_out_s: f64,
}

/// Analytic per-stage breakdown of a partitioned execution: busy time per
/// contiguous stage plus the boundary transfers each stage emits (INT8
/// features on `boundary_link` — the MPAI boundary quantizes before the
/// hop, paper §III).  This is what the pipelined dispatcher charges on
/// its simulated clock.
pub fn stage_latencies(
    graph: &Graph,
    partition: &Partition,
    accels: &BTreeMap<String, &dyn Accelerator>,
    boundary_link: &Link,
) -> Result<Vec<StageLatency>, EstimateError> {
    let stages = partition.contiguous_stages(graph)?;
    let cross = partition.cross_edges(graph, 1);
    let mut out = Vec::with_capacity(stages.len());
    for (k, s) in stages.iter().enumerate() {
        let accel = accels
            .get(&s.accel)
            .ok_or_else(|| EstimateError::UnknownAccelerator {
                name: s.accel.clone(),
                layer: graph.layers[s.layers[0]].name.clone(),
            })?;
        let busy_s = s
            .layers
            .iter()
            .map(|&i| accel.layer_cost(&graph.layers[i], &graph.in_shapes(i)).total_s())
            .sum();
        let transfer_out_s = if k + 1 == stages.len() {
            0.0
        } else {
            cross
                .iter()
                .filter(|&&(pi, _, _)| s.layers.contains(&pi))
                .map(|&(_, _, bytes)| boundary_link.transfer_s(bytes))
                .sum()
        };
        out.push(StageLatency {
            accel: s.accel.clone(),
            layers: s.layers.clone(),
            busy_s,
            transfer_out_s,
        });
    }
    Ok(out)
}

/// Latency breakdown of a partitioned inference.
#[derive(Debug, Clone)]
pub struct PartitionLatency {
    /// (accelerator name, busy seconds) per segment, in execution order.
    pub segments: Vec<(String, f64)>,
    /// Cross-boundary transfer seconds.
    pub transfers_s: f64,
    /// Host input delivery + output readback.
    pub host_io_s: f64,
    /// Per-inference invocation costs of every engaged accelerator.
    pub invoke_s: f64,
}

impl PartitionLatency {
    /// Sequential (non-pipelined) single-frame latency.
    pub fn total_s(&self) -> f64 {
        self.segments.iter().map(|s| s.1).sum::<f64>()
            + self.transfers_s
            + self.host_io_s
            + self.invoke_s
    }

    pub fn total_ms(&self) -> f64 {
        self.total_s() * 1e3
    }

    /// Pipelined steady-state throughput: the slowest stage bounds FPS
    /// (the coordinator overlaps segment k of frame i with segment k+1 of
    /// frame i-1).
    pub fn pipelined_fps(&self) -> f64 {
        let bottleneck = self
            .segments
            .iter()
            .map(|s| s.1)
            .fold(self.transfers_s + self.host_io_s, f64::max);
        1.0 / bottleneck.max(1e-12)
    }
}

/// Estimate a partitioned execution.
///
/// `accels` maps partition names to models; `boundary_link` carries
/// cross-segment tensors (INT8 width — the MPAI boundary quantizes features
/// before the hop, paper §III).  Errors (instead of panicking) when the
/// partition references an accelerator absent from the map or has no
/// linear stage order — a malformed `--partition` flag must not abort the
/// serve loop.
pub fn partition_latency(
    graph: &Graph,
    partition: &Partition,
    accels: &BTreeMap<String, &dyn Accelerator>,
    boundary_link: &Link,
) -> Result<PartitionLatency, EstimateError> {
    let stages = stage_latencies(graph, partition, accels, boundary_link)?;
    latency_from_stages(graph, &stages, accels)
}

/// Assemble a [`PartitionLatency`] from already-computed stage latencies
/// (the pipeline planner computes stages once and derives both the plan
/// and the latency from them — no second per-layer cost walk).
pub fn latency_from_stages(
    graph: &Graph,
    stages: &[StageLatency],
    accels: &BTreeMap<String, &dyn Accelerator>,
) -> Result<PartitionLatency, EstimateError> {
    let transfers_s: f64 = stages.iter().map(|s| s.transfer_out_s).sum();

    // Host IO: input delivery to the first stage's accelerator, output
    // readback from every later stage's engine, per-invocation costs of
    // every engaged engine.
    let mut host_io_s = 0.0;
    let mut invoke_s = 0.0;
    for (k, s) in stages.iter().enumerate() {
        let accel = accels
            .get(&s.accel)
            .ok_or_else(|| EstimateError::UnknownAccelerator {
                name: s.accel.clone(),
                layer: graph
                    .layers
                    .get(s.layers.first().copied().unwrap_or_default())
                    .map(|l| l.name.clone())
                    .unwrap_or_default(),
            })?;
        let mc = if k == 0 {
            let eb = accel.precision().bytes();
            let in_bytes: usize = graph
                .layers
                .iter()
                .filter(|l| matches!(l.op, Op::Input))
                .map(|l| l.out.numel() * eb)
                .sum();
            accel.model_cost(graph, in_bytes, 0)
        } else {
            accel.model_cost(graph, 0, 64) // output readback only
        };
        host_io_s += mc.host_io_s;
        invoke_s += mc.invoke_s + mc.param_stream_s;
    }

    Ok(PartitionLatency {
        segments: stages
            .iter()
            .map(|s| (s.accel.clone(), s.busy_s))
            .collect(),
        transfers_s,
        host_io_s,
        invoke_s,
    })
}

/// Energy estimate (joules/frame) for a single-accelerator run.
pub fn energy_per_frame(accel: &dyn Accelerator, lat: &NetworkLatency) -> f64 {
    accel.power().energy_j(lat.total_s(), lat.total_s())
}

/// Convenience: latency + energy for one device on one graph.
pub fn device_report(accel: &dyn Accelerator, graph: &Graph) -> (NetworkLatency, f64) {
    let lat = network_latency(accel, graph);
    let e = energy_per_frame(accel, &lat);
    (lat, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::dpu::Dpu;
    use crate::accel::interconnect::links;
    use crate::accel::vpu::Vpu;
    use crate::net::models::ursonet;

    fn accel_map<'a>(dpu: &'a Dpu, vpu: &'a Vpu) -> BTreeMap<String, &'a dyn Accelerator> {
        let mut m: BTreeMap<String, &dyn Accelerator> = BTreeMap::new();
        m.insert("dpu".into(), dpu);
        m.insert("vpu".into(), vpu);
        m
    }

    #[test]
    fn mpai_partition_between_dpu_and_vpu_alone() {
        // Table I shape: DPU < MPAI(DPU+VPU) < VPU on full UrsoNet.
        let g = ursonet::build_full();
        let (dpu, vpu) = (Dpu, Vpu);
        let accels = accel_map(&dpu, &vpu);

        let cut = g.layers.iter().position(|l| l.name == "gap").unwrap();
        let p = Partition::two_way(&g, cut, "dpu", "vpu");
        let mpai = partition_latency(&g, &p, &accels, &links::USB3).unwrap().total_s();

        let dpu_only = crate::accel::traits::network_latency(&Dpu, &g).total_s();
        let vpu_only = crate::accel::traits::network_latency(&Vpu, &g).total_s();
        // (same graph form on all three paths: un-compiled, for comparability)
        assert!(
            dpu_only < mpai && mpai < vpu_only,
            "dpu {dpu_only:.3} mpai {mpai:.3} vpu {vpu_only:.3}"
        );
    }

    #[test]
    fn mpai_near_paper_latency() {
        // Table I: DPU+VPU inference 79 ms (1.49x the DPU row). Assert the
        // modeled ratio in [1.05, 2.2].
        let g = ursonet::build_full();
        let (dpu, vpu) = (Dpu, Vpu);
        let accels = accel_map(&dpu, &vpu);
        let cut = g.layers.iter().position(|l| l.name == "gap").unwrap();
        let p = Partition::two_way(&g, cut, "dpu", "vpu");
        let mpai = partition_latency(&g, &p, &accels, &links::USB3).unwrap().total_s();
        let dpu_only = crate::accel::traits::network_latency(&Dpu, &g).total_s();
        let ratio = mpai / dpu_only;
        assert!((1.05..2.2).contains(&ratio), "MPAI/DPU ratio {ratio}");
    }

    #[test]
    fn single_accel_partition_matches_network_latency_layers() {
        let g = ursonet::build_lite();
        let (dpu, vpu) = (Dpu, Vpu);
        let accels = accel_map(&dpu, &vpu);
        let p = Partition::single(&g, "dpu");
        let pl = partition_latency(&g, &p, &accels, &links::USB3).unwrap();
        let nl = crate::accel::traits::network_latency(&Dpu, &g);
        assert!((pl.segments[0].1 - nl.layers_s).abs() < 1e-12);
        assert_eq!(pl.transfers_s, 0.0);
    }

    #[test]
    fn pipelined_fps_at_least_sequential() {
        let g = ursonet::build_full();
        let (dpu, vpu) = (Dpu, Vpu);
        let accels = accel_map(&dpu, &vpu);
        let cut = g.layers.iter().position(|l| l.name == "gap").unwrap();
        let p = Partition::two_way(&g, cut, "dpu", "vpu");
        let pl = partition_latency(&g, &p, &accels, &links::USB3).unwrap();
        assert!(pl.pipelined_fps() >= 1.0 / pl.total_s() - 1e-9);
    }

    #[test]
    fn unknown_accelerator_is_an_error_not_a_panic() {
        // ISSUE satellite: a partition naming an engine outside the model
        // map must surface a typed error (a bad --partition flag must not
        // abort the serve loop).
        let g = ursonet::build_lite();
        let (dpu, vpu) = (Dpu, Vpu);
        let accels = accel_map(&dpu, &vpu);
        let p = Partition::single(&g, "npu");
        let err = partition_latency(&g, &p, &accels, &links::USB3).unwrap_err();
        assert!(
            matches!(err, EstimateError::UnknownAccelerator { .. }),
            "{err}"
        );
        assert!(err.to_string().contains("npu"), "{err}");
    }

    #[test]
    fn stage_latencies_sum_to_partition_latency() {
        let g = ursonet::build_full();
        let (dpu, vpu) = (Dpu, Vpu);
        let accels = accel_map(&dpu, &vpu);
        let cut = g.layers.iter().position(|l| l.name == "gap").unwrap();
        let p = Partition::two_way(&g, cut, "dpu", "vpu");
        let stages = stage_latencies(&g, &p, &accels, &links::USB3).unwrap();
        let pl = partition_latency(&g, &p, &accels, &links::USB3).unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].accel, "dpu");
        assert_eq!(stages[1].accel, "vpu");
        let busy: f64 = stages.iter().map(|s| s.busy_s).sum();
        let seg: f64 = pl.segments.iter().map(|s| s.1).sum();
        assert!((busy - seg).abs() < 1e-12);
        let xfer: f64 = stages.iter().map(|s| s.transfer_out_s).sum();
        assert!((xfer - pl.transfers_s).abs() < 1e-12);
        // Only the non-final stage emits boundary traffic on a chain cut.
        assert!(stages[0].transfer_out_s > 0.0);
        assert_eq!(stages[1].transfer_out_s, 0.0);
    }
}
