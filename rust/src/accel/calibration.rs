//! Calibration constants for every accelerator model, with sources.
//!
//! Absolute-number fidelity is *not* the goal (the substrate is a simulator,
//! not the authors' bench — see the brief); the constants are chosen so the
//! models land within ~25% of published device measurements and reproduce
//! the paper's orderings and ratios (Fig. 2 crossovers, Table I ordering).
//!
//! Sources per device:
//!
//! * **DPU (DPUCZDX8G-B4096)** — AMD PG338: 4096-MAC core, 1.2 TOPS INT8 at
//!   ~300 MHz (= 0.6 TMAC/s).  ZCU104 implements two cores; single-frame
//!   latency uses one (the second serves a parallel stream).  Sustained conv
//!   efficiency ~0.55 of peak per Vitis AI model-zoo latencies.
//! * **Edge TPU (Coral)** — Google datasheet: 4 TOPS INT8 (2 TMAC/s), 8 MB
//!   on-chip SRAM of which ~6.5 MB usable for parameters (compiler docs).
//!   Models larger than SRAM stream weights per inference over the host
//!   link (PCIe on the DevBoard SoM) — the documented "off-chip" penalty
//!   and the mechanism behind Fig. 2's ResNet-50 crossover.
//! * **MyriadX VPU (NCS2)** — Intel: ~0.7 TFLOPS FP16 effective from 16
//!   SHAVEs + AI engine (0.35 TMAC/s); 2.5 MB CMX scratchpad; USB3 host
//!   link.  Depthwise conv collapses SHAVE utilization (no channel
//!   parallelism to vectorize) — the MobileNetV2 mechanism of Fig. 2.
//! * **Cortex-A53** — 4-core 1.2–1.5 GHz; NEON 128-bit: 4 FP32 (8 FP16)
//!   MACs/cycle/core.  Sustained dense-conv throughput calibrated to
//!   ~10% of peak (published Eigen/NNPACK A53 benchmarks), FP16 ~2.3x FP32.

/// DPUCZDX8G-B4096 on ZCU104 (PL @ 300 MHz).
pub mod dpu {
    /// Sustained MAC/s for dense conv on one B4096 core (0.6 TMAC peak).
    pub const PEAK_MACS: f64 = 0.6e12;
    /// Conv efficiency vs peak (Vitis AI model-zoo calibration).
    pub const CONV_EFF: f64 = 0.55;
    /// Depthwise conv efficiency (no channel reuse in the PE array).
    pub const DW_EFF: f64 = 0.15;
    /// Vector/elementwise ops throughput (MAC-equivalents/s).
    pub const VECTOR_OPS: f64 = 40e9;
    /// DDR4 bandwidth available to the DPU AXI masters (shared with PS).
    pub const DDR_BPS: f64 = 2.4e9;
    /// Per-layer instruction fetch/dispatch overhead.
    pub const LAYER_OVERHEAD_S: f64 = 50e-6;
    /// Per-inference invocation cost (runtime descriptor setup).
    pub const INVOKE_S: f64 = 1.0e-3;
    /// PL+DPU power (ZCU104 measurements in the Vitis AI docs).
    pub const IDLE_W: f64 = 4.0;
    pub const ACTIVE_W: f64 = 9.5;
}

/// Edge TPU (Coral DevBoard SoM).
pub mod tpu {
    /// 4 TOPS INT8 = 2e12 MAC/s.
    pub const PEAK_MACS: f64 = 2.0e12;
    pub const CONV_EFF: f64 = 0.25;
    pub const DW_EFF: f64 = 0.10;
    pub const VECTOR_OPS: f64 = 30e9;
    /// SRAM usable for parameter caching.
    pub const PARAM_SRAM_BYTES: usize = 6_500_000;
    /// Host link effective bandwidth (PCIe on the SoM).
    pub const LINK_BPS: f64 = 320e6;
    /// Fixed host-link turnaround per inference.
    pub const LINK_LATENCY_S: f64 = 0.5e-3;
    /// Per-layer cost when the model is fully SRAM-resident.
    pub const LAYER_OVERHEAD_S: f64 = 10e-6;
    /// Extra per-layer transaction cost while streaming weights.
    pub const STREAM_LAYER_OVERHEAD_S: f64 = 50e-6;
    pub const IDLE_W: f64 = 0.5;
    pub const ACTIVE_W: f64 = 2.0;
}

/// Intel MyriadX VPU (NCS2 USB stick).
pub mod vpu {
    /// 0.7 TFLOPS FP16 = 0.35e12 MAC/s.
    pub const PEAK_MACS: f64 = 0.35e12;
    pub const CONV_EFF: f64 = 0.40;
    /// Depthwise collapses SHAVE vectorization.
    pub const DW_EFF: f64 = 0.015;
    pub const VECTOR_OPS: f64 = 25e9;
    /// On-package LPDDR bandwidth (weights for FC layers stream from DDR).
    pub const DDR_BPS: f64 = 1.2e9;
    /// USB3 effective bandwidth.
    pub const LINK_BPS: f64 = 350e6;
    pub const LINK_LATENCY_S: f64 = 1.5e-3;
    /// Per-layer scheduling cost (LEON RTOS dispatch to SHAVEs).
    pub const LAYER_OVERHEAD_S: f64 = 150e-6;
    pub const IDLE_W: f64 = 0.7;
    pub const ACTIVE_W: f64 = 1.8;
}

/// Cortex-A53 host CPU (DevBoard @1.5 GHz FP32, ZCU104 @1.2 GHz FP16).
pub mod cpu {
    /// Sustained conv GMAC/s, FP32, 4xA53 @1.5 GHz (DevBoard).
    pub const FP32_MACS: f64 = 1.7e9;
    /// Sustained conv GMAC/s, FP16, 4xA53 @1.2 GHz (ZCU104; 2x SIMD width,
    /// calibrated to the paper's 9890 ms / 4210 ms ratio ≈ 2.35).
    pub const FP16_MACS: f64 = 4.0e9;
    pub const VECTOR_OPS: f64 = 4e9;
    /// LPDDR4 effective bandwidth for streaming weights.
    pub const DDR_BPS: f64 = 3.2e9;
    pub const LAYER_OVERHEAD_S: f64 = 10e-6;
    pub const IDLE_W: f64 = 1.2;
    pub const ACTIVE_W: f64 = 3.5;
    /// Preprocessing (bilinear resample) throughput, bytes/s of source
    /// pixels: DevBoard scalar path vs ZCU104 NEON path — calibrated to the
    /// Table I Total-minus-Inference gaps (38 ms vs 13 ms at 1280x960x3).
    pub const PREPROCESS_BPS_DEVBOARD: f64 = 100e6;
    pub const PREPROCESS_BPS_ZCU104: f64 = 290e6;
}

/// Camera frame geometry of the paper (Table I: 1280x960x3).
pub const PAPER_FRAME_BYTES: usize = 1280 * 960 * 3;

#[cfg(test)]
mod tests {
    #[test]
    fn orderings_that_the_models_rely_on() {
        use super::*;
        // INT8 engines outrun the FP16 engine at peak.
        assert!(tpu::PEAK_MACS > vpu::PEAK_MACS);
        assert!(dpu::PEAK_MACS > vpu::PEAK_MACS * vpu::CONV_EFF);
        // Depthwise efficiency collapse is worst on the VPU.
        assert!(vpu::DW_EFF < tpu::DW_EFF && vpu::DW_EFF < dpu::DW_EFF);
        // CPU FP16 ~2.35x FP32 (Table I CPU rows).
        let r = cpu::FP16_MACS / cpu::FP32_MACS;
        assert!((2.0..2.6).contains(&r));
    }
}
