//! DPUCZDX8G model — the MPSoC PL INT8 inference engine (paper §II).
//!
//! "Deep pipelined 8-bit architecture, with the processing elements taking
//! full advantage of the fine-grained building blocks ... on-chip memory is
//! used for storing input activations, intermediate feature-maps ... an
//! instruction scheduler fetches instructions from off-chip memory."
//!
//! Model: one B4096 core; conv layers run at `CONV_EFF` of the 0.6 TMAC/s
//! peak; depthwise at `DW_EFF` (no channel reuse across the PE array);
//! FC layers are DDR-bandwidth-bound (weights stream from off-chip, exactly
//! once, no caching); each layer pays an instruction-dispatch overhead.
//! Input arrives over the on-chip AXI HP port (Fig. 1).

use crate::accel::calibration::dpu as cal;
use crate::accel::interconnect::links;
use crate::accel::traits::{Accelerator, LayerCost, ModelCost, PowerModel, Precision};
use crate::net::graph::Graph;
use crate::net::layers::{Layer, Op, Shape};

/// DPUCZDX8G-B4096 on the ZCU104.
#[derive(Debug, Clone, Default)]
pub struct Dpu;

impl Accelerator for Dpu {
    fn name(&self) -> &str {
        "dpu"
    }

    fn hosting_device(&self) -> &str {
        "ZCU104"
    }

    fn precision(&self) -> Precision {
        Precision::Int8
    }

    fn supports(&self, layer: &Layer, _in: &[Shape]) -> bool {
        // The DPU executes the standard CNN operator set; softmax runs on
        // the host in the Vitis AI flow.
        !matches!(layer.op, Op::Input)
    }

    fn layer_cost(&self, layer: &Layer, in_shapes: &[Shape]) -> LayerCost {
        let macs = layer.macs(in_shapes) as f64;
        let params = layer.params(in_shapes) as f64; // INT8: 1 byte each

        let compute_s = match &layer.op {
            Op::Conv { .. } if layer.is_depthwise(in_shapes) => {
                macs / (cal::PEAK_MACS * cal::DW_EFF)
            }
            Op::Conv { .. } => macs / (cal::PEAK_MACS * cal::CONV_EFF),
            Op::Dense { .. } => macs / (cal::PEAK_MACS * cal::CONV_EFF),
            _ => macs / cal::VECTOR_OPS,
        };
        // Weights stream from DDR each inference (the DPU fetches weights
        // per-layer); activations stay in on-chip BRAM with data reuse
        // (paper §II: "data reuse is applied to reduce external memory
        // bandwidth requirements").
        let memory_s = params / cal::DDR_BPS;
        LayerCost {
            compute_s,
            memory_s,
            overhead_s: cal::LAYER_OVERHEAD_S,
        }
    }

    fn model_cost(&self, _graph: &Graph, in_bytes: usize, out_bytes: usize) -> ModelCost {
        ModelCost {
            param_stream_s: 0.0, // charged per-layer via memory_s
            host_io_s: links::AXI_HP.transfer_s(in_bytes) + links::AXI_HP.transfer_s(out_bytes),
            invoke_s: cal::INVOKE_S,
        }
    }

    fn power(&self) -> PowerModel {
        PowerModel {
            idle_w: cal::IDLE_W,
            active_w: cal::ACTIVE_W,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::traits::deployed_latency;
    use crate::net::models;

    #[test]
    fn ursonet_full_near_paper_latency() {
        // Table I: DPU inference 53 ms. Model within ~40%.
        let g = models::ursonet::build_full();
        let lat = deployed_latency(&Dpu, &g).total_ms();
        assert!((35.0..75.0).contains(&lat), "DPU UrsoNet {lat} ms");
    }

    #[test]
    fn depthwise_slower_per_mac_than_dense_conv() {
        let g = models::mobilenet_v2::build(1000);
        let dpu = Dpu;
        let mut dw_rate = f64::INFINITY;
        let mut conv_rate: f64 = 0.0;
        for (i, l) in g.layers.iter().enumerate() {
            let ins = g.in_shapes(i);
            let macs = l.macs(&ins) as f64;
            if macs == 0.0 || !matches!(l.op, Op::Conv { .. }) {
                continue;
            }
            let r = macs / dpu.layer_cost(l, &ins).compute_s;
            if l.is_depthwise(&ins) {
                dw_rate = dw_rate.min(r);
            } else {
                conv_rate = conv_rate.max(r);
            }
        }
        assert!(dw_rate < conv_rate / 2.0);
    }

    #[test]
    fn supports_whole_zoo() {
        let dpu = Dpu;
        for g in models::fig2_models() {
            for (i, l) in g.layers.iter().enumerate() {
                if matches!(l.op, Op::Input) {
                    continue;
                }
                assert!(dpu.supports(l, &g.in_shapes(i)), "{}", l.name);
            }
        }
    }

    #[test]
    fn fastest_table1_engine() {
        // Table I ordering: DPU < TPU < VPU on UrsoNet inference latency.
        use crate::accel::{tpu::Tpu, vpu::Vpu};
        let g = models::ursonet::build_full();
        let dpu = deployed_latency(&Dpu, &g).total_s();
        let tpu = deployed_latency(&Tpu, &g).total_s();
        let vpu = deployed_latency(&Vpu, &g).total_s();
        assert!(dpu < tpu && tpu < vpu, "dpu {dpu} tpu {tpu} vpu {vpu}");
    }
}
