//! Cortex-A53 host-CPU model — the Table I software-baseline rows, and the
//! host that runs preprocessing for every accelerator row.

use crate::accel::calibration::cpu as cal;
use crate::accel::traits::{Accelerator, LayerCost, ModelCost, PowerModel, Precision};
use crate::net::graph::Graph;
use crate::net::layers::{Layer, Op, Shape};

/// Which board hosts the CPU (affects clock + preprocessing path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Host {
    /// Coral DevBoard: 4xA53 @1.5 GHz, FP32 inference (Table I row 1).
    DevBoard,
    /// ZCU104 PS: 4xA53 @1.2 GHz, FP16 inference (Table I row 2).
    Zcu104,
}

/// A53 CPU software inference.
#[derive(Debug, Clone, Copy)]
pub struct Cpu {
    pub host: Host,
}

impl Cpu {
    pub fn devboard() -> Cpu {
        Cpu {
            host: Host::DevBoard,
        }
    }

    pub fn zcu104() -> Cpu {
        Cpu { host: Host::Zcu104 }
    }

    fn macs_per_s(&self) -> f64 {
        match self.host {
            Host::DevBoard => cal::FP32_MACS,
            Host::Zcu104 => cal::FP16_MACS,
        }
    }

    /// Preprocessing (bilinear resample + normalize) time for a camera
    /// frame of `src_bytes` — the Table I "Total minus Inference" column.
    pub fn preprocess_s(&self, src_bytes: usize) -> f64 {
        let bps = match self.host {
            Host::DevBoard => cal::PREPROCESS_BPS_DEVBOARD,
            Host::Zcu104 => cal::PREPROCESS_BPS_ZCU104,
        };
        src_bytes as f64 / bps
    }
}

impl Accelerator for Cpu {
    fn name(&self) -> &str {
        "cpu"
    }

    fn hosting_device(&self) -> &str {
        match self.host {
            Host::DevBoard => "DevBoard",
            Host::Zcu104 => "ZCU104",
        }
    }

    fn precision(&self) -> Precision {
        match self.host {
            Host::DevBoard => Precision::Fp32,
            Host::Zcu104 => Precision::Fp16,
        }
    }

    fn supports(&self, layer: &Layer, _in: &[Shape]) -> bool {
        !matches!(layer.op, Op::Input) // software runs everything
    }

    fn layer_cost(&self, layer: &Layer, in_shapes: &[Shape]) -> LayerCost {
        let macs = layer.macs(in_shapes) as f64;
        let elem = self.precision().bytes() as f64;
        let params_bytes = layer.params(in_shapes) as f64 * elem;
        let compute_s = match &layer.op {
            // Depthwise vectorizes tolerably on NEON (channel-last loops).
            Op::Conv { .. } | Op::Dense { .. } => macs / self.macs_per_s(),
            _ => macs / cal::VECTOR_OPS,
        };
        LayerCost {
            compute_s,
            memory_s: params_bytes / cal::DDR_BPS,
            overhead_s: cal::LAYER_OVERHEAD_S,
        }
    }

    fn model_cost(&self, _graph: &Graph, _in: usize, _out: usize) -> ModelCost {
        ModelCost::default() // data is already in host memory
    }

    fn power(&self) -> PowerModel {
        PowerModel {
            idle_w: cal::IDLE_W,
            active_w: cal::ACTIVE_W,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::calibration::PAPER_FRAME_BYTES;
    use crate::accel::traits::deployed_latency;
    use crate::net::models;

    #[test]
    fn ursonet_full_fp32_near_paper() {
        // Table I: Cortex-A53 FP32 inference 9890 ms; within ~40%.
        let lat = deployed_latency(&Cpu::devboard(), &models::ursonet::build_full()).total_s();
        assert!((6.0..14.0).contains(&lat), "CPU FP32 {lat} s");
    }

    #[test]
    fn ursonet_full_fp16_near_paper() {
        // Table I: Cortex-A53 FP16 inference 4210 ms; within ~40%.
        let lat = deployed_latency(&Cpu::zcu104(), &models::ursonet::build_full()).total_s();
        assert!((2.5..6.0).contains(&lat), "CPU FP16 {lat} s");
    }

    #[test]
    fn fp16_speedup_matches_table1_ratio() {
        // 9890/4210 = 2.35; assert [1.8, 2.8].
        let g = models::ursonet::build_full();
        let r = deployed_latency(&Cpu::devboard(), &g).total_s()
            / deployed_latency(&Cpu::zcu104(), &g).total_s();
        assert!((1.8..2.8).contains(&r), "FP32/FP16 ratio {r}");
    }

    #[test]
    fn preprocess_near_table1_gaps() {
        // DevBoard: 187-149 = 38 ms; ZCU104 (DPU row): 66-53 = 13 ms.
        let dev = Cpu::devboard().preprocess_s(PAPER_FRAME_BYTES) * 1e3;
        let zcu = Cpu::zcu104().preprocess_s(PAPER_FRAME_BYTES) * 1e3;
        assert!((25.0..50.0).contains(&dev), "DevBoard preprocess {dev} ms");
        assert!((8.0..18.0).contains(&zcu), "ZCU104 preprocess {zcu} ms");
    }

    #[test]
    fn cpu_orders_of_magnitude_slower_than_dpu() {
        use crate::accel::dpu::Dpu;
        let g = models::ursonet::build_full();
        let cpu = deployed_latency(&Cpu::devboard(), &g).total_s();
        let dpu = deployed_latency(&Dpu, &g).total_s();
        assert!(cpu / dpu > 50.0, "CPU/DPU ratio {}", cpu / dpu);
    }
}
