//! MyriadX VPU model (paper §II).
//!
//! "2 general-purpose LEON4 CPUs, 16 SIMD & VLIW programmable cores
//! [SHAVEs], hardware imaging filters, and a dedicated AI accelerator
//! engine ... models are built on 16-bit floating-point arithmetic."
//!
//! Model: FP16 compute at `CONV_EFF` of 0.35 TMAC/s; depthwise convolutions
//! collapse utilization (`DW_EFF`, no channel vectorization across SHAVE
//! lanes — the MobileNetV2 mechanism of Fig. 2); FC weights stream from the
//! on-package LPDDR; every layer pays a LEON-dispatch overhead; inputs and
//! outputs cross the USB3 link (NCS2 form factor).

use crate::accel::calibration::vpu as cal;
use crate::accel::interconnect::links;
use crate::accel::traits::{Accelerator, LayerCost, ModelCost, PowerModel, Precision};
use crate::net::graph::Graph;
use crate::net::layers::{Layer, Op, Shape};

/// Intel MyriadX on the NCS2 USB stick.
#[derive(Debug, Clone, Default)]
pub struct Vpu;

impl Accelerator for Vpu {
    fn name(&self) -> &str {
        "vpu"
    }

    fn hosting_device(&self) -> &str {
        "NCS2"
    }

    fn precision(&self) -> Precision {
        Precision::Fp16
    }

    fn supports(&self, layer: &Layer, _in: &[Shape]) -> bool {
        !matches!(layer.op, Op::Input)
    }

    fn layer_cost(&self, layer: &Layer, in_shapes: &[Shape]) -> LayerCost {
        let macs = layer.macs(in_shapes) as f64;
        let params_bytes = layer.params(in_shapes) as f64 * 2.0; // FP16
        let compute_s = match &layer.op {
            Op::Conv { .. } if layer.is_depthwise(in_shapes) => {
                macs / (cal::PEAK_MACS * cal::DW_EFF)
            }
            Op::Conv { .. } | Op::Dense { .. } => macs / (cal::PEAK_MACS * cal::CONV_EFF),
            _ => macs / cal::VECTOR_OPS,
        };
        // Conv weights are small enough to persist in CMX across rows; FC
        // weights stream from LPDDR (the dominant term for the heads).
        let memory_s = match &layer.op {
            Op::Dense { .. } => params_bytes / cal::DDR_BPS,
            _ => 0.0,
        };
        LayerCost {
            compute_s,
            memory_s,
            overhead_s: cal::LAYER_OVERHEAD_S,
        }
    }

    fn model_cost(&self, _graph: &Graph, in_bytes: usize, out_bytes: usize) -> ModelCost {
        ModelCost {
            param_stream_s: 0.0,
            host_io_s: links::USB3.transfer_s(in_bytes) + links::USB3.transfer_s(out_bytes),
            invoke_s: 0.0, // turnaround folded into the USB transfers
        }
    }

    fn power(&self) -> PowerModel {
        PowerModel {
            idle_w: cal::IDLE_W,
            active_w: cal::ACTIVE_W,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::tpu::Tpu;
    use crate::accel::traits::deployed_latency;
    use crate::net::models;

    #[test]
    fn fig2_mobilenet_tpu_wins_big() {
        // Paper: "for small networks (MobileNet V2), TPU provides 8x more
        // FPS than VPU" — assert the ratio in [4, 14].
        let g = models::mobilenet_v2::build(1000);
        let vpu_fps = deployed_latency(&Vpu, &g).fps();
        let tpu_fps = deployed_latency(&Tpu, &g).fps();
        let ratio = tpu_fps / vpu_fps;
        assert!((4.0..14.0).contains(&ratio), "TPU/VPU ratio {ratio}");
    }

    #[test]
    fn fig2_resnet50_vpu_wins() {
        // Paper: "for a larger network (ResNet-50), VPU delivers 2x
        // throughput" — assert VPU ahead by [1.3, 3.0].
        let g = models::resnet50::build(1000);
        let vpu_fps = deployed_latency(&Vpu, &g).fps();
        let tpu_fps = deployed_latency(&Tpu, &g).fps();
        let ratio = vpu_fps / tpu_fps;
        assert!((1.3..3.0).contains(&ratio), "VPU/TPU ratio {ratio}");
    }

    #[test]
    fn fig2_inception_v4_parity_near_10fps() {
        // Paper: "for Inception V4, both accelerators sustain ~10 FPS".
        let g = models::inception_v4::build(1000);
        let vpu_fps = deployed_latency(&Vpu, &g).fps();
        let tpu_fps = deployed_latency(&Tpu, &g).fps();
        assert!((5.0..16.0).contains(&vpu_fps), "VPU {vpu_fps} FPS");
        assert!((5.0..16.0).contains(&tpu_fps), "TPU {tpu_fps} FPS");
    }

    #[test]
    fn ursonet_full_near_paper_latency() {
        // Table I: VPU inference 246 ms; model within ~2x (the substrate is
        // calibrated jointly against Fig. 2 ratios and Table I — see
        // EXPERIMENTS.md for the recorded deviation).
        let lat = deployed_latency(&Vpu, &models::ursonet::build_full()).total_ms();
        assert!((100.0..350.0).contains(&lat), "VPU UrsoNet {lat} ms");
    }

    #[test]
    fn head_only_latency_small() {
        // The MPAI head segment (FC layers on features) must cost only a
        // few ms — the premise of the 79 ms MPAI row.
        use crate::net::graph::Graph;
        use crate::net::layers::{Act, Shape};
        let mut g = Graph::new("head");
        let x = g.input("features", Shape::vec(6 * 8 * 128));
        let b = g.dense("fc_bneck", x, 128, Act::Relu);
        g.dense("fc_loc", b, 3, Act::None);
        g.dense("fc_ori", b, 4, Act::None);
        let lat = deployed_latency(&Vpu, &g).total_ms();
        assert!(lat < 15.0, "head latency {lat} ms");
    }
}
