//! Accelerator substrates: timing + power models of every device in the
//! paper's testbed, plus the interconnects between them (DESIGN.md §1, §4.3).

pub mod calibration;
pub mod cpu;
pub mod dpu;
pub mod estimate;
pub mod interconnect;
pub mod tpu;
pub mod traits;
pub mod vpu;

pub use cpu::Cpu;
pub use dpu::Dpu;
pub use estimate::{device_report, partition_latency, PartitionLatency};
pub use interconnect::{links, Link};
pub use tpu::Tpu;
pub use vpu::Vpu;
pub use traits::{deployed_latency, network_latency, Accelerator, LayerCost, NetworkLatency, Precision};
