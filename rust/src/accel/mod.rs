//! Accelerator substrates: timing + power models of every device in the
//! paper's testbed, plus the interconnects between them (DESIGN.md §1, §4.3).

pub mod calibration;
pub mod cpu;
pub mod dpu;
pub mod estimate;
pub mod interconnect;
pub mod tpu;
pub mod traits;
pub mod vpu;

pub use cpu::Cpu;
pub use dpu::Dpu;
pub use estimate::{
    device_report, latency_from_stages, partition_latency, stage_latencies, EstimateError,
    PartitionLatency, StageLatency,
};
pub use interconnect::{links, Link};
pub use tpu::Tpu;
pub use vpu::Vpu;
pub use traits::{deployed_latency, network_latency, Accelerator, LayerCost, NetworkLatency, Precision};

/// Accelerator model by its partition-vocabulary name ("dpu", "vpu",
/// "tpu", "cpu" — the ZCU104-hosted A53 for the software fallback).
pub fn by_name(name: &str) -> Option<Box<dyn Accelerator>> {
    match name {
        "dpu" => Some(Box::new(Dpu)),
        "vpu" => Some(Box::new(Vpu)),
        "tpu" => Some(Box::new(Tpu)),
        "cpu" => Some(Box::new(Cpu::zcu104())),
        _ => None,
    }
}
