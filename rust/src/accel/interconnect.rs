//! Interconnect models: the links that carry frames, features, and weights
//! between the MPSoC host and the accelerators (Fig. 1 of the paper).

/// A point-to-point link with fixed turnaround latency and bandwidth.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    pub name: &'static str,
    /// Effective (not line-rate) bandwidth in bytes/second.
    pub bandwidth_bps: f64,
    /// Fixed per-transfer latency (driver + protocol turnaround).
    pub latency_s: f64,
}

impl Link {
    pub const fn new(name: &'static str, bandwidth_bps: f64, latency_s: f64) -> Link {
        Link {
            name,
            bandwidth_bps,
            latency_s,
        }
    }

    /// Time to move `bytes` in one transfer.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Time for `n` back-to-back transfers of `bytes` each (latency paid
    /// once per transfer — no pipelining across transactions).
    pub fn transfers_s(&self, n: usize, bytes: usize) -> f64 {
        n as f64 * self.transfer_s(bytes)
    }
}

/// The links present in the MPAI topology (Fig. 1), with effective rates.
pub mod links {
    use super::Link;

    /// PS <-> PL (DPU) AXI HP port on the MPSoC: on-chip, wide, low latency.
    pub const AXI_HP: Link = Link::new("axi-hp", 2.0e9, 20e-6);
    /// USB 3.0 to the NCS2 (VPU): effective app-level throughput.
    pub const USB3: Link = Link::new("usb3", 350e6, 1.5e-3);
    /// USB 2.0 fallback (NCS2 plugged into a USB2 port — ablation).
    pub const USB2: Link = Link::new("usb2", 35e6, 2.5e-3);
    /// PCIe x1 to the Edge TPU SoM on the DevBoard.
    pub const PCIE_X1: Link = Link::new("pcie-x1", 350e6, 0.3e-3);
    /// Camera CSI-2 ingest into the MPSoC.
    pub const CSI2: Link = Link::new("csi2", 1.2e9, 100e-6);

    /// Link by name (the CLI `--link` vocabulary).
    pub fn by_name(name: &str) -> Option<Link> {
        match name {
            "axi-hp" => Some(AXI_HP),
            "usb3" => Some(USB3),
            "usb2" => Some(USB2),
            "pcie-x1" => Some(PCIE_X1),
            "csi2" => Some(CSI2),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_includes_latency() {
        let l = Link::new("t", 100e6, 1e-3);
        // 1 MB at 100 MB/s = 10 ms + 1 ms latency.
        let t = l.transfer_s(1_000_000);
        assert!((t - 0.011).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_costs_latency_only() {
        let l = links::USB3;
        assert!((l.transfer_s(0) - l.latency_s).abs() < 1e-12);
    }

    #[test]
    fn repeated_transfers_scale() {
        let l = Link::new("t", 1e9, 1e-4);
        assert!((l.transfers_s(10, 1000) - 10.0 * l.transfer_s(1000)).abs() < 1e-12);
    }

    #[test]
    fn topology_orderings() {
        use links::*;
        // On-chip beats every off-chip link.
        assert!(AXI_HP.bandwidth_bps > USB3.bandwidth_bps);
        assert!(AXI_HP.latency_s < USB3.latency_s);
        // USB3 ≫ USB2.
        assert!(USB3.bandwidth_bps / USB2.bandwidth_bps > 5.0);
    }

    #[test]
    fn link_lookup_round_trips() {
        for l in [links::AXI_HP, links::USB3, links::USB2, links::PCIE_X1, links::CSI2] {
            assert_eq!(links::by_name(l.name), Some(l));
        }
        assert_eq!(links::by_name("carrier-pigeon"), None);
    }

    #[test]
    fn feature_transfer_is_cheap_over_usb3() {
        // The MPAI boundary tensor (6x8x128 int8 = 6 KiB) must be dominated
        // by turnaround latency, not bandwidth — the premise of the paper's
        // DPU+VPU latency (79 ms ≈ DPU 53 + head + transfers).
        let t = links::USB3.transfer_s(6 * 8 * 128);
        assert!(t < 2.0e-3, "feature transfer {t}");
    }
}
