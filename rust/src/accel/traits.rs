//! Accelerator model interface.
//!
//! Every device (DPU, Edge TPU, MyriadX VPU, Cortex-A53) implements
//! [`Accelerator`]: a *layer-level cycle-approximate* timing + power model.
//! Latency of a layer is `max(compute, memory) + overhead` — the roofline
//! shape that governs all four real devices — and whole-network latency adds
//! the device's per-inference fixed costs (host I/O, parameter streaming).
//!
//! The models are calibrated against published device figures (see
//! `calibration.rs` for every constant and its source) and are the
//! substitute for the paper's physical testbed (DESIGN.md §1).

use crate::net::graph::Graph;
use crate::net::layers::{Layer, Shape};

/// Arithmetic the device commits to (Table I "Model Precision" column).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    Fp16,
    Int8,
}

impl Precision {
    pub fn bytes(self) -> usize {
        match self {
            Precision::Fp32 => 4,
            Precision::Fp16 => 2,
            Precision::Int8 => 1,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Precision::Fp32 => "FP32",
            Precision::Fp16 => "FP16",
            Precision::Int8 => "INT8",
        }
    }
}

/// Cost breakdown for one layer on one device (seconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCost {
    /// MAC-array / vector-unit busy time.
    pub compute_s: f64,
    /// Activation + weight movement time (overlappable with compute).
    pub memory_s: f64,
    /// Non-overlappable per-layer cost (instruction dispatch, kernel launch).
    pub overhead_s: f64,
}

impl LayerCost {
    /// Double-buffered execution: compute overlaps memory; overhead does not.
    pub fn total_s(&self) -> f64 {
        self.compute_s.max(self.memory_s) + self.overhead_s
    }
}

/// Per-inference costs that are not attributable to a single layer.
#[derive(Debug, Clone, Copy, Default)]
pub struct ModelCost {
    /// Parameter streaming (weights that do not fit on-chip), per inference.
    pub param_stream_s: f64,
    /// Host -> device input transfer + device -> host output transfer.
    pub host_io_s: f64,
    /// Fixed invocation cost (driver, descriptor setup).
    pub invoke_s: f64,
}

impl ModelCost {
    pub fn total_s(&self) -> f64 {
        self.param_stream_s + self.host_io_s + self.invoke_s
    }
}

/// Simple two-state power model (watts).
#[derive(Debug, Clone, Copy)]
pub struct PowerModel {
    pub idle_w: f64,
    pub active_w: f64,
}

impl PowerModel {
    /// Energy for `busy_s` seconds of activity in a `window_s` window.
    pub fn energy_j(&self, busy_s: f64, window_s: f64) -> f64 {
        let idle = (window_s - busy_s).max(0.0);
        self.active_w * busy_s + self.idle_w * idle
    }
}

/// The accelerator model interface.
pub trait Accelerator {
    /// Short name used by partitions/telemetry ("dpu", "tpu", "vpu", "cpu").
    fn name(&self) -> &str;

    /// Hosting device string (Table I "Hosting Device" column).
    fn hosting_device(&self) -> &str;

    fn precision(&self) -> Precision;

    /// Whether the device can execute this layer at all (feasibility check
    /// used by the partitioner).
    fn supports(&self, layer: &Layer, in_shapes: &[Shape]) -> bool;

    /// Timing of one layer (batch 1).
    fn layer_cost(&self, layer: &Layer, in_shapes: &[Shape]) -> LayerCost;

    /// Per-inference fixed costs for running `graph` end-to-end, given the
    /// bytes entering and leaving the device.
    fn model_cost(&self, graph: &Graph, in_bytes: usize, out_bytes: usize) -> ModelCost;

    fn power(&self) -> PowerModel;
}

/// Full-network single-device latency estimate.
#[derive(Debug, Clone, Default)]
pub struct NetworkLatency {
    pub layers_s: f64,
    pub model: ModelCost,
    pub per_layer: Vec<(String, LayerCost)>,
}

impl NetworkLatency {
    pub fn total_s(&self) -> f64 {
        self.layers_s + self.model.total_s()
    }

    pub fn total_ms(&self) -> f64 {
        self.total_s() * 1e3
    }

    pub fn fps(&self) -> f64 {
        1.0 / self.total_s()
    }
}

/// Estimate the *deployed* graph on `accel`: applies the graph compiler
/// (BN folding + activation fusion — what the vendor toolflows execute)
/// before timing.  This is what Fig. 2 / Table I consume.
pub fn deployed_latency(accel: &dyn Accelerator, graph: &Graph) -> NetworkLatency {
    let compiled = crate::net::compiler::compile(graph);
    network_latency(accel, &compiled)
}

/// Estimate running `graph` exactly as given on `accel` (batch 1).
pub fn network_latency(accel: &dyn Accelerator, graph: &Graph) -> NetworkLatency {
    let mut out = NetworkLatency::default();
    for (i, layer) in graph.layers.iter().enumerate() {
        if matches!(layer.op, crate::net::layers::Op::Input) {
            continue;
        }
        let in_shapes = graph.in_shapes(i);
        let c = accel.layer_cost(layer, &in_shapes);
        out.layers_s += c.total_s();
        out.per_layer.push((layer.name.clone(), c));
    }
    let eb = accel.precision().bytes();
    let in_bytes: usize = graph
        .layers
        .iter()
        .filter(|l| matches!(l.op, crate::net::layers::Op::Input))
        .map(|l| l.out.numel() * eb)
        .sum();
    let out_bytes: usize = graph
        .outputs()
        .iter()
        .map(|&i| graph.layers[i].out.numel() * eb)
        .sum();
    out.model = accel.model_cost(graph, in_bytes, out_bytes);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_cost_overlap_semantics() {
        let c = LayerCost {
            compute_s: 3.0,
            memory_s: 5.0,
            overhead_s: 1.0,
        };
        assert_eq!(c.total_s(), 6.0); // max(3,5)+1
    }

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::Fp32.bytes(), 4);
        assert_eq!(Precision::Fp16.bytes(), 2);
        assert_eq!(Precision::Int8.bytes(), 1);
    }

    #[test]
    fn power_energy() {
        let p = PowerModel {
            idle_w: 1.0,
            active_w: 5.0,
        };
        // 0.5s busy in a 2s window: 0.5*5 + 1.5*1 = 4 J.
        assert!((p.energy_j(0.5, 2.0) - 4.0).abs() < 1e-12);
    }
}
