//! MPT tensor-container reader (rust half of python/compile/mpt.py).
//!
//! Format (pinned by python/tests/test_mpt.py and the tests below):
//!
//! ```text
//! magic   4 bytes  b"MPT1"
//! hdr_len u32 LE
//! header  JSON     {"tensors": [{"name","dtype","shape","offset","nbytes"}]}
//! data    raw LE tensor bytes; offsets relative to end-of-header, 64-aligned
//! ```

use std::collections::BTreeMap;
use std::fs;
use std::path::Path;

use crate::util::json::{self, Json};

/// Tensor dtype tags shared with the python writer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    U8,
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype, MptError> {
        match s {
            "u8" => Ok(Dtype::U8),
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => Err(MptError::Format(format!("unknown dtype {other:?}"))),
        }
    }

    pub fn size(self) -> usize {
        match self {
            Dtype::U8 => 1,
            Dtype::F32 | Dtype::I32 => 4,
        }
    }
}

/// One decoded tensor.
#[derive(Debug, Clone)]
pub enum Tensor {
    U8(Vec<u8>),
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::U8(v) => v.len(),
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_u8(&self) -> Option<&[u8]> {
        match self {
            Tensor::U8(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// A named tensor with shape.
#[derive(Debug, Clone)]
pub struct Entry {
    pub shape: Vec<usize>,
    pub data: Tensor,
}

impl Entry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug)]
pub enum MptError {
    Io(std::io::Error),
    Format(String),
    Header(json::JsonError),
}

impl std::fmt::Display for MptError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MptError::Io(e) => write!(f, "mpt io error: {e}"),
            MptError::Format(m) => write!(f, "mpt format error: {m}"),
            MptError::Header(e) => write!(f, "mpt header json error: {e}"),
        }
    }
}

impl std::error::Error for MptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            MptError::Io(e) => Some(e),
            MptError::Header(e) => Some(e),
            MptError::Format(_) => None,
        }
    }
}

impl From<std::io::Error> for MptError {
    fn from(e: std::io::Error) -> MptError {
        MptError::Io(e)
    }
}

impl From<json::JsonError> for MptError {
    fn from(e: json::JsonError) -> MptError {
        MptError::Header(e)
    }
}

/// Read a full MPT file into a name->Entry map (order-preserving keys are
/// not needed by consumers; lookups are by name).
pub fn read_mpt(path: &Path) -> Result<BTreeMap<String, Entry>, MptError> {
    let bytes = fs::read(path)?;
    read_mpt_bytes(&bytes)
}

pub fn read_mpt_bytes(bytes: &[u8]) -> Result<BTreeMap<String, Entry>, MptError> {
    if bytes.len() < 8 || &bytes[..4] != b"MPT1" {
        return Err(MptError::Format("bad magic (want MPT1)".into()));
    }
    let hdr_len = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
    let hdr_end = 8 + hdr_len;
    if bytes.len() < hdr_end {
        return Err(MptError::Format("truncated header".into()));
    }
    let header = std::str::from_utf8(&bytes[8..hdr_end])
        .map_err(|e| MptError::Format(format!("header not utf-8: {e}")))?;
    let parsed = json::parse(header)?;
    let tensors = parsed
        .req("tensors")?
        .as_arr()
        .ok_or_else(|| MptError::Format("tensors must be an array".into()))?;

    let mut out = BTreeMap::new();
    for t in tensors {
        let name = t
            .req("name")?
            .as_str()
            .ok_or_else(|| MptError::Format("name must be a string".into()))?
            .to_string();
        let dtype = Dtype::parse(
            t.req("dtype")?
                .as_str()
                .ok_or_else(|| MptError::Format("dtype must be a string".into()))?,
        )?;
        let shape = t
            .req("shape")?
            .as_usize_vec()
            .ok_or_else(|| MptError::Format("shape must be a usize array".into()))?;
        let offset = t
            .req("offset")?
            .as_usize()
            .ok_or_else(|| MptError::Format("offset must be a usize".into()))?;
        let nbytes = t
            .req("nbytes")?
            .as_usize()
            .ok_or_else(|| MptError::Format("nbytes must be a usize".into()))?;

        let numel: usize = shape.iter().product();
        if numel * dtype.size() != nbytes {
            return Err(MptError::Format(format!(
                "tensor {name}: shape {shape:?} x {} != nbytes {nbytes}",
                dtype.size()
            )));
        }
        let start = hdr_end + offset;
        let end = start + nbytes;
        if bytes.len() < end {
            return Err(MptError::Format(format!("tensor {name}: data out of range")));
        }
        let raw = &bytes[start..end];
        let data = match dtype {
            Dtype::U8 => Tensor::U8(raw.to_vec()),
            Dtype::F32 => Tensor::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            Dtype::I32 => Tensor::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
        };
        out.insert(name, Entry { shape, data });
    }
    Ok(out)
}

/// Write an MPT file (rust writer — used by telemetry export and tests).
pub fn write_mpt(path: &Path, tensors: &[(String, Vec<usize>, Tensor)]) -> Result<(), MptError> {
    const ALIGN: usize = 64;
    let mut entries = Vec::new();
    let mut blobs: Vec<(usize, Vec<u8>)> = Vec::new();
    let mut offset = 0usize;
    for (name, shape, data) in tensors {
        let (dtype, raw): (&str, Vec<u8>) = match data {
            Tensor::U8(v) => ("u8", v.clone()),
            Tensor::F32(v) => ("f32", v.iter().flat_map(|x| x.to_le_bytes()).collect()),
            Tensor::I32(v) => ("i32", v.iter().flat_map(|x| x.to_le_bytes()).collect()),
        };
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            return Err(MptError::Format(format!(
                "tensor {name}: shape {shape:?} != len {}",
                data.len()
            )));
        }
        let pad = (ALIGN - offset % ALIGN) % ALIGN;
        offset += pad;
        let mut e = Json::obj();
        e.set("name", Json::from(name.as_str()));
        e.set("dtype", Json::from(dtype));
        e.set("shape", Json::Arr(shape.iter().map(|&d| Json::from(d)).collect()));
        e.set("offset", Json::from(offset));
        e.set("nbytes", Json::from(raw.len()));
        entries.push(e);
        offset += raw.len();
        blobs.push((pad, raw));
    }
    let mut header = Json::obj();
    header.set("tensors", Json::Arr(entries));
    let header_bytes = header.to_string().into_bytes();

    let mut out = Vec::with_capacity(8 + header_bytes.len() + offset);
    out.extend_from_slice(b"MPT1");
    out.extend_from_slice(&(header_bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(&header_bytes);
    for (pad, raw) in blobs {
        out.extend(std::iter::repeat(0u8).take(pad));
        out.extend_from_slice(&raw);
    }
    fs::write(path, out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(tensors: Vec<(String, Vec<usize>, Tensor)>) -> BTreeMap<String, Entry> {
        let dir = std::env::temp_dir().join(format!("mpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("t{}.mpt", tensors.len()));
        write_mpt(&path, &tensors).unwrap();
        let back = read_mpt(&path).unwrap();
        std::fs::remove_file(&path).ok();
        back
    }

    #[test]
    fn roundtrip_all_dtypes() {
        let back = roundtrip(vec![
            ("a".into(), vec![2, 3], Tensor::U8(vec![1, 2, 3, 4, 5, 6])),
            ("b".into(), vec![4], Tensor::F32(vec![1.5, -2.5, 0.0, 3.25])),
            ("c".into(), vec![2, 1], Tensor::I32(vec![-7, 9])),
        ]);
        assert_eq!(back["a"].shape, vec![2, 3]);
        assert_eq!(back["a"].data.as_u8().unwrap(), &[1, 2, 3, 4, 5, 6]);
        assert_eq!(back["b"].data.as_f32().unwrap(), &[1.5, -2.5, 0.0, 3.25]);
        assert_eq!(back["c"].data.as_i32().unwrap(), &[-7, 9]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_mpt_bytes(b"NOPE\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncated() {
        let dir = std::env::temp_dir();
        let path = dir.join("trunc.mpt");
        write_mpt(
            &path,
            &[("x".into(), vec![4], Tensor::F32(vec![1.0, 2.0, 3.0, 4.0]))],
        )
        .unwrap();
        let bytes = fs::read(&path).unwrap();
        let cut = &bytes[..bytes.len() - 4];
        assert!(read_mpt_bytes(cut).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_shape_nbytes_mismatch() {
        // Hand-craft a header with inconsistent nbytes.
        let hdr = r#"{"tensors":[{"name":"x","dtype":"f32","shape":[2],"offset":0,"nbytes":4}]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(b"MPT1");
        bytes.extend_from_slice(&(hdr.len() as u32).to_le_bytes());
        bytes.extend_from_slice(hdr.as_bytes());
        bytes.extend_from_slice(&[0u8; 8]);
        assert!(read_mpt_bytes(&bytes).is_err());
    }

    #[test]
    fn offsets_aligned() {
        // 5-byte tensor followed by another: second offset must be 64.
        let back = roundtrip(vec![
            ("a".into(), vec![5], Tensor::U8(vec![0; 5])),
            ("b".into(), vec![2], Tensor::F32(vec![1.0, 2.0])),
        ]);
        assert_eq!(back["b"].data.as_f32().unwrap(), &[1.0, 2.0]);
    }

    #[test]
    fn f32_le_byte_order_pinned() {
        // 1.0f32 LE = 00 00 80 3F — byte-level pin mirrored in test_mpt.py.
        let dir = std::env::temp_dir();
        let path = dir.join("pin.mpt");
        write_mpt(&path, &[("x".into(), vec![1], Tensor::F32(vec![1.0]))]).unwrap();
        let bytes = fs::read(&path).unwrap();
        let tail = &bytes[bytes.len() - 4..];
        assert_eq!(tail, &[0x00, 0x00, 0x80, 0x3F]);
        std::fs::remove_file(&path).ok();
    }
}
