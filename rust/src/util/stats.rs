//! Descriptive statistics + a micro-bench harness (criterion substitute).
//!
//! The offline environment has no criterion; `Bench` gives the benches a
//! warmup / repeat / percentile loop with stable text output so the paper
//! tables are regenerated as plain rows (DESIGN.md §5).

use std::time::{Duration, Instant};

/// Running summary of a sample set (latencies, errors, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Summary {
        Summary::default()
    }

    pub fn from(samples: &[f64]) -> Summary {
        let mut s = Summary::new();
        for &x in samples {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Percentile by linear interpolation (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// P² (piecewise-parabolic) single-quantile estimator (Jain & Chlamtac,
/// CACM 1985): five markers track one running quantile in O(1) memory, no
/// retained samples.  Below five observations the estimate interpolates
/// the raw buffer exactly, matching [`Summary::percentile`].
#[derive(Debug, Clone, PartialEq)]
pub struct P2 {
    p: f64,
    /// Marker heights (the first five raw samples until primed).
    q: [f64; 5],
    /// Actual marker positions (1-based, as in the paper).
    n: [f64; 5],
    /// Desired marker positions.
    np: [f64; 5],
    /// Desired-position increment per observation.
    dn: [f64; 5],
    count: u64,
}

impl P2 {
    pub fn new(p: f64) -> P2 {
        assert!((0.0..=1.0).contains(&p), "quantile must be in [0, 1]");
        P2 {
            p,
            q: [0.0; 5],
            n: [1.0, 2.0, 3.0, 4.0, 5.0],
            np: [1.0, 1.0 + 2.0 * p, 1.0 + 4.0 * p, 3.0 + 2.0 * p, 5.0],
            dn: [0.0, p / 2.0, p, (1.0 + p) / 2.0, 1.0],
            count: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn add(&mut self, x: f64) {
        if self.count < 5 {
            self.q[self.count as usize] = x;
            self.count += 1;
            if self.count == 5 {
                self.q.sort_by(f64::total_cmp);
            }
            return;
        }
        self.count += 1;
        // Locate the cell holding x, growing the extreme markers in place.
        let k = if x < self.q[0] {
            self.q[0] = x;
            0
        } else if x >= self.q[4] {
            self.q[4] = x;
            3
        } else {
            (1..4).find(|&i| x < self.q[i]).unwrap_or(4) - 1
        };
        for i in (k + 1)..5 {
            self.n[i] += 1.0;
        }
        for i in 0..5 {
            self.np[i] += self.dn[i];
        }
        // Nudge interior markers toward their desired positions; parabolic
        // prediction when it stays monotone, linear otherwise.
        for i in 1..4 {
            let d = self.np[i] - self.n[i];
            if (d >= 1.0 && self.n[i + 1] - self.n[i] > 1.0)
                || (d <= -1.0 && self.n[i - 1] - self.n[i] < -1.0)
            {
                let d = d.signum();
                let cand = self.parabolic(i, d);
                self.q[i] = if self.q[i - 1] < cand && cand < self.q[i + 1] {
                    cand
                } else {
                    self.linear(i, d)
                };
                self.n[i] += d;
            }
        }
    }

    fn parabolic(&self, i: usize, d: f64) -> f64 {
        let (q, n) = (&self.q, &self.n);
        q[i] + d / (n[i + 1] - n[i - 1])
            * ((n[i] - n[i - 1] + d) * (q[i + 1] - q[i]) / (n[i + 1] - n[i])
                + (n[i + 1] - n[i] - d) * (q[i] - q[i - 1]) / (n[i] - n[i - 1]))
    }

    fn linear(&self, i: usize, d: f64) -> f64 {
        let j = if d > 0.0 { i + 1 } else { i - 1 };
        self.q[i] + d * (self.q[j] - self.q[i]) / (self.n[j] - self.n[i])
    }

    /// Current estimate: NaN when empty, exact (sorted-buffer interpolation)
    /// below five samples, the middle marker once primed.
    pub fn estimate(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if self.count < 5 {
            let mut buf = self.q;
            let buf = &mut buf[..self.count as usize];
            buf.sort_by(f64::total_cmp);
            let rank = self.p * (buf.len() - 1) as f64;
            let (lo, hi) = (rank.floor() as usize, rank.ceil() as usize);
            if lo == hi {
                return buf[lo];
            }
            let frac = rank - lo as f64;
            return buf[lo] * (1.0 - frac) + buf[hi] * frac;
        }
        self.q[2]
    }
}

/// Bounded streaming digest for long-horizon runs: exact count/min/max,
/// Welford mean and variance, and P² estimates for p50/p99 — O(1) memory
/// regardless of sample count.  Replaces the unbounded per-frame latency
/// `Vec` in million-frame daemon runs.
///
/// Equality (`PartialEq`) is bit-exact over the internal state, which is
/// deterministic for a fixed *insertion order*: replaying the same trace
/// on `SimClock` produces identical digests.  A permutation of the same
/// samples (threaded executors surface completions in host-scheduling
/// order) may shift the quantile estimates — compare the order-insensitive
/// parts (count, min, max, and mean to rounding) across executors instead.
#[derive(Debug, Clone, PartialEq)]
pub struct Streaming {
    count: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
    p50: P2,
    p99: P2,
}

impl Default for Streaming {
    fn default() -> Streaming {
        Streaming::new()
    }
}

impl Streaming {
    pub fn new() -> Streaming {
        Streaming {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            p50: P2::new(0.5),
            p99: P2::new(0.99),
        }
    }

    pub fn from(samples: &[f64]) -> Streaming {
        let mut s = Streaming::new();
        for &x in samples {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        let delta = x - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        self.p50.add(x);
        self.p99.add(x);
    }

    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        (self.m2 / (self.count - 1) as f64).sqrt()
    }

    /// Same fold identities as [`Summary`]: +inf when empty.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Same fold identities as [`Summary`]: -inf when empty.
    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn p50(&self) -> f64 {
        self.p50.estimate()
    }

    pub fn p99(&self) -> f64 {
        self.p99.estimate()
    }
}

/// Micro-bench: warmup then timed iterations; reports wall-clock percentiles.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Bench {
        Bench {
            warmup_iters: 3,
            iters: 20,
        }
    }
}

/// Result of one bench run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<42} mean {:>12?}  p50 {:>12?}  min {:>12?}  max {:>12?}  (n={})",
            self.name, self.mean, self.p50, self.min, self.max, self.iters
        )
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Bench {
        Bench {
            warmup_iters,
            iters,
        }
    }

    /// Time `f` (called once per iteration); returns percentile summary.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            mean: Duration::from_secs_f64(s.mean()),
            p50: Duration::from_secs_f64(s.p50()),
            min: Duration::from_secs_f64(s.min()),
            max: Duration::from_secs_f64(s.max()),
            iters: self.iters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(s.p50(), 30.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert_eq!(s.percentile(25.0), 20.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from(&[0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
        assert!(s.p50().is_nan() && s.p99().is_nan());
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn empty_extrema_and_spread() {
        // Documented sentinel behavior of the fold-based extrema: an empty
        // sample set yields the fold identities, and std is defined as 0
        // below two samples.
        let s = Summary::new();
        assert_eq!(s.min(), f64::INFINITY);
        assert_eq!(s.max(), f64::NEG_INFINITY);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn single_sample_every_percentile_is_that_sample() {
        let s = Summary::from(&[42.5]);
        for p in [0.0, 1.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 42.5, "p{p}");
        }
        assert_eq!(s.mean(), 42.5);
        assert_eq!((s.min(), s.max()), (42.5, 42.5));
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn tied_samples_collapse_percentiles() {
        let s = Summary::from(&[5.0, 5.0, 5.0, 5.0]);
        for p in [0.0, 33.3, 50.0, 66.6, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 5.0, "p{p}");
        }
        assert_eq!(s.std(), 0.0);
        // Partial ties interpolate only across the distinct tail.
        let s = Summary::from(&[1.0, 1.0, 1.0, 3.0]);
        assert_eq!(s.p50(), 1.0);
        assert_eq!(s.percentile(100.0), 3.0);
        assert!((s.percentile(75.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn unsorted_input_percentiles_sort_first() {
        let s = Summary::from(&[9.0, 1.0, 5.0]);
        assert_eq!(s.p50(), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 9.0);
    }

    #[test]
    fn p2_below_five_samples_matches_exact_percentile() {
        let samples = [9.0, 1.0, 5.0, 3.0];
        for n in 1..=4 {
            let exact = Summary::from(&samples[..n]);
            for p in [0.5, 0.99] {
                let mut est = P2::new(p);
                for &x in &samples[..n] {
                    est.add(x);
                }
                assert_eq!(
                    est.estimate(),
                    exact.percentile(p * 100.0),
                    "n={n} p={p}"
                );
            }
        }
        assert!(P2::new(0.5).estimate().is_nan());
    }

    #[test]
    fn p2_tracks_exact_quantiles_on_random_streams() {
        use crate::util::prng::Prng;
        let mut rng = Prng::new(0x4D50_4149);
        // Bands are deliberately loose — this guards gross estimator bugs
        // (wrong marker updates), not publication-grade accuracy.
        for (dist, tol) in [("uniform", 0.05), ("exponential", 0.75)] {
            let mut p50 = P2::new(0.5);
            let mut p99 = P2::new(0.99);
            let mut exact = Summary::new();
            for _ in 0..10_000 {
                let x = match dist {
                    "uniform" => rng.f64(),
                    _ => rng.exponential(1.0),
                };
                p50.add(x);
                p99.add(x);
                exact.add(x);
            }
            assert!(
                (p50.estimate() - exact.p50()).abs() < tol,
                "{dist} p50: est {} exact {}",
                p50.estimate(),
                exact.p50()
            );
            assert!(
                (p99.estimate() - exact.p99()).abs() < tol,
                "{dist} p99: est {} exact {}",
                p99.estimate(),
                exact.p99()
            );
        }
    }

    #[test]
    fn streaming_moments_match_summary() {
        let samples: Vec<f64> = (0..200).map(|i| ((i * 7919) % 101) as f64).collect();
        let s = Streaming::from(&samples);
        let exact = Summary::from(&samples);
        assert_eq!(s.len(), exact.len());
        assert_eq!(s.min(), exact.min());
        assert_eq!(s.max(), exact.max());
        assert!((s.mean() - exact.mean()).abs() < 1e-12);
        assert!((s.std() - exact.std()).abs() < 1e-9);
        // Quantiles are estimates once past five samples: accuracy band only.
        assert!((s.p50() - exact.p50()).abs() < 5.0);
        assert!((s.p99() - exact.p99()).abs() < 5.0);
    }

    #[test]
    fn streaming_is_order_deterministic_and_comparable() {
        let samples = [0.4, 0.1, 0.9, 0.2, 0.7, 0.3, 0.8];
        assert_eq!(Streaming::from(&samples), Streaming::from(&samples));
        let mut reversed = samples;
        reversed.reverse();
        let (a, b) = (Streaming::from(&samples), Streaming::from(&reversed));
        // Order-insensitive parts always agree (to rounding) ...
        assert_eq!(a.len(), b.len());
        assert_eq!(a.min(), b.min());
        assert_eq!(a.max(), b.max());
        assert!((a.mean() - b.mean()).abs() < 1e-12);
    }

    #[test]
    fn streaming_empty_is_nan_with_fold_identities() {
        let s = Streaming::new();
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert!(s.mean().is_nan());
        assert!(s.p50().is_nan() && s.p99().is_nan());
        assert_eq!(s.min(), f64::INFINITY);
        assert_eq!(s.max(), f64::NEG_INFINITY);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn bench_counts_iterations() {
        let mut count = 0;
        let b = Bench::new(2, 5);
        let r = b.run("noop", || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean >= Duration::ZERO);
    }
}
