//! Descriptive statistics + a micro-bench harness (criterion substitute).
//!
//! The offline environment has no criterion; `Bench` gives the benches a
//! warmup / repeat / percentile loop with stable text output so the paper
//! tables are regenerated as plain rows (DESIGN.md §5).

use std::time::{Duration, Instant};

/// Running summary of a sample set (latencies, errors, ...).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Summary {
        Summary::default()
    }

    pub fn from(samples: &[f64]) -> Summary {
        let mut s = Summary::new();
        for &x in samples {
            s.add(x);
        }
        s
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        let var = self
            .samples
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / (self.samples.len() - 1) as f64;
        var.sqrt()
    }

    /// Percentile by linear interpolation (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (sorted.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            sorted[lo]
        } else {
            let frac = rank - lo as f64;
            sorted[lo] * (1.0 - frac) + sorted[hi] * frac
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Micro-bench: warmup then timed iterations; reports wall-clock percentiles.
pub struct Bench {
    pub warmup_iters: usize,
    pub iters: usize,
}

impl Default for Bench {
    fn default() -> Bench {
        Bench {
            warmup_iters: 3,
            iters: 20,
        }
    }
}

/// Result of one bench run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub mean: Duration,
    pub p50: Duration,
    pub min: Duration,
    pub max: Duration,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "{:<42} mean {:>12?}  p50 {:>12?}  min {:>12?}  max {:>12?}  (n={})",
            self.name, self.mean, self.p50, self.min, self.max, self.iters
        )
    }
}

impl Bench {
    pub fn new(warmup_iters: usize, iters: usize) -> Bench {
        Bench {
            warmup_iters,
            iters,
        }
    }

    /// Time `f` (called once per iteration); returns percentile summary.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            mean: Duration::from_secs_f64(s.mean()),
            p50: Duration::from_secs_f64(s.p50()),
            min: Duration::from_secs_f64(s.min()),
            max: Duration::from_secs_f64(s.max()),
            iters: self.iters,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.std() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let s = Summary::from(&[10.0, 20.0, 30.0, 40.0, 50.0]);
        assert_eq!(s.p50(), 30.0);
        assert_eq!(s.percentile(0.0), 10.0);
        assert_eq!(s.percentile(100.0), 50.0);
        assert_eq!(s.percentile(25.0), 20.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = Summary::from(&[0.0, 10.0]);
        assert_eq!(s.percentile(50.0), 5.0);
    }

    #[test]
    fn empty_summary_is_nan() {
        let s = Summary::new();
        assert!(s.mean().is_nan());
        assert!(s.percentile(50.0).is_nan());
        assert!(s.p50().is_nan() && s.p99().is_nan());
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn empty_extrema_and_spread() {
        // Documented sentinel behavior of the fold-based extrema: an empty
        // sample set yields the fold identities, and std is defined as 0
        // below two samples.
        let s = Summary::new();
        assert_eq!(s.min(), f64::INFINITY);
        assert_eq!(s.max(), f64::NEG_INFINITY);
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn single_sample_every_percentile_is_that_sample() {
        let s = Summary::from(&[42.5]);
        for p in [0.0, 1.0, 25.0, 50.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 42.5, "p{p}");
        }
        assert_eq!(s.mean(), 42.5);
        assert_eq!((s.min(), s.max()), (42.5, 42.5));
        assert_eq!(s.std(), 0.0);
    }

    #[test]
    fn tied_samples_collapse_percentiles() {
        let s = Summary::from(&[5.0, 5.0, 5.0, 5.0]);
        for p in [0.0, 33.3, 50.0, 66.6, 99.0, 100.0] {
            assert_eq!(s.percentile(p), 5.0, "p{p}");
        }
        assert_eq!(s.std(), 0.0);
        // Partial ties interpolate only across the distinct tail.
        let s = Summary::from(&[1.0, 1.0, 1.0, 3.0]);
        assert_eq!(s.p50(), 1.0);
        assert_eq!(s.percentile(100.0), 3.0);
        assert!((s.percentile(75.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn unsorted_input_percentiles_sort_first() {
        let s = Summary::from(&[9.0, 1.0, 5.0]);
        assert_eq!(s.p50(), 5.0);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 9.0);
    }

    #[test]
    fn bench_counts_iterations() {
        let mut count = 0;
        let b = Bench::new(2, 5);
        let r = b.run("noop", || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.iters, 5);
        assert!(r.mean >= Duration::ZERO);
    }
}
