//! Machine-readable bench results: `BENCH_<name>.json` emission.
//!
//! Every ablation bench prints a human report and asserts its own gates;
//! this module adds the CI contract on top: when `MPAI_BENCH_JSON` names
//! a directory, a bench calls [`emit`] with its headline metrics and a
//! `BENCH_<name>.json` document lands there.  The CI bench-smoke job
//! uploads those files as workflow artifacts and the `bench-gate` binary
//! compares them against the committed `bench/baseline.json`, failing on
//! regressions past the tolerance (see EXPERIMENTS.md for the baseline
//! refresh procedure).
//!
//! Emission is a no-op without the env var, so local `cargo bench` runs
//! stay filesystem-clean.

use std::path::PathBuf;

use crate::util::json::Json;

/// Env var naming the output directory for bench JSON results.
pub const BENCH_JSON_ENV: &str = "MPAI_BENCH_JSON";

/// Serialize one bench's metrics to `$MPAI_BENCH_JSON/BENCH_<name>.json`
/// (creating the directory if needed).  Non-finite metric values are
/// recorded as `null` — the gate treats them as unbaselined.  Returns the
/// path written, `None` when emission is disabled.  I/O failures panic:
/// in CI a silently missing result file would read as "nothing to gate".
pub fn emit(name: &str, metrics: &[(&str, f64)]) -> Option<PathBuf> {
    let dir = std::env::var_os(BENCH_JSON_ENV)?;
    let dir = PathBuf::from(dir);
    std::fs::create_dir_all(&dir)
        .unwrap_or_else(|e| panic!("creating bench-json dir {dir:?}: {e}"));

    let mut doc = Json::obj();
    doc.set("name", Json::Str(name.to_string()));
    let mut m = Json::obj();
    for (k, v) in metrics {
        let val = if v.is_finite() { Json::Num(*v) } else { Json::Null };
        m.set(k, val);
    }
    doc.set("metrics", m);

    let path = dir.join(format!("BENCH_{name}.json"));
    std::fs::write(&path, format!("{doc}\n"))
        .unwrap_or_else(|e| panic!("writing {path:?}: {e}"));
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn emits_parseable_document_when_env_set() {
        // Serialize/parse round-trip without touching process env (tests
        // run in parallel): exercise the document shape directly.
        let mut doc = Json::obj();
        doc.set("name", Json::Str("wall_clock".into()));
        let mut m = Json::obj();
        m.set("modeled_fps", Json::Num(18.71));
        m.set("unbaselined", Json::Null);
        doc.set("metrics", m);
        let parsed = json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.req("name").unwrap().as_str(), Some("wall_clock"));
        assert_eq!(
            parsed.req("metrics").unwrap().get("modeled_fps").and_then(Json::as_f64),
            Some(18.71)
        );
        assert_eq!(
            parsed.req("metrics").unwrap().get("unbaselined"),
            Some(&Json::Null)
        );
    }

    #[test]
    fn emit_is_a_no_op_without_the_env_var() {
        if std::env::var_os(BENCH_JSON_ENV).is_none() {
            assert_eq!(emit("noop_probe", &[("x", 1.0)]), None);
        }
    }
}
