//! Minimal JSON parser/serializer.
//!
//! The offline build environment carries no `serde` facade, so the manifest,
//! calibration stats, config files, and telemetry exports go through this
//! self-contained implementation.  It supports the full JSON data model
//! (objects, arrays, strings with escapes, numbers, booleans, null) and
//! preserves object key order (insertion order) — the manifest is written by
//! python with sorted keys and diffed in tests, so order stability matters.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object; `BTreeMap` (sorted keys) since the python writers use
    /// `sort_keys=True` and deterministic serialization aids diffing.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors -------------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    // -- accessors ----------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError {
            offset: 0,
            msg: format!("missing required key {key:?}"),
        })
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of numbers -> Vec<usize> (shapes in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    // -- mutation helpers (for writers) --------------------------------------

    pub fn set(&mut self, key: &str, val: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        }
    }

    pub fn push(&mut self, val: Json) {
        if let Json::Arr(v) = self {
            v.push(val);
        }
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

// ---------------------------------------------------------------------------
// Parser.
// ---------------------------------------------------------------------------

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected literal {lit}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000C}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("bad unicode escape"))?);
                            self.pos -= 1; // compensated below
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Serializer.
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for ch in s.chars() {
        match ch {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"k":"v"},"s":"x\ny","t":true}"#;
        let v = parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(parse(&out).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(4.0).to_string(), "4");
        assert_eq!(Json::Num(4.5).to_string(), "4.5");
    }

    #[test]
    fn usize_vec() {
        let v = parse("[4, 96, 128, 3]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![4, 96, 128, 3]);
        assert_eq!(parse("[1.5]").unwrap().as_usize_vec(), None);
    }

    #[test]
    fn req_missing_key_errors() {
        let v = parse(r#"{"a": 1}"#).unwrap();
        assert!(v.req("a").is_ok());
        assert!(v.req("b").is_err());
    }
}
