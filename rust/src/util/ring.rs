//! Batched MPSC ring channel, std-only (DESIGN.md §4.13): a
//! `Mutex<VecDeque>` + `Condvar` pair whose send and receive sides move
//! *whole batches* per lock round.
//!
//! `std::mpsc` pays one rendezvous (lock + wakeup) per token, which
//! dominates the threaded executor at high fan-in.  Here a sender can
//! publish a full completion batch in one `send_batch`, and the receiver
//! drains *everything queued* into a caller-owned buffer per
//! `recv_batch` — so the number of wakeups scales with batches, not
//! tokens, and the receive buffer is recycled by the caller (zero
//! steady-state allocation).
//!
//! Close semantics mirror `mpsc`: dropping every [`Sender`] wakes the
//! receiver with an empty drain (`recv_batch` returns 0); dropping the
//! [`Receiver`] turns subsequent sends into counted no-ops (`false`).
//! Lock poisoning is ignored — the queue holds plain data, valid
//! regardless of a panicking holder.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};

struct State<T> {
    queue: VecDeque<T>,
    senders: usize,
    receiver_alive: bool,
}

struct Shared<T> {
    state: Mutex<State<T>>,
    available: Condvar,
}

impl<T> Shared<T> {
    fn lock(&self) -> MutexGuard<'_, State<T>> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Producer half; clone freely (the channel is multi-producer).
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Consumer half (single consumer: batched drains share one cursor).
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// A fresh channel pair.
pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        state: Mutex::new(State {
            queue: VecDeque::new(),
            senders: 1,
            receiver_alive: true,
        }),
        available: Condvar::new(),
    });
    (
        Sender {
            shared: Arc::clone(&shared),
        },
        Receiver { shared },
    )
}

impl<T> Sender<T> {
    /// Queue one item; `false` when the receiver is gone (item dropped).
    pub fn send(&self, item: T) -> bool {
        let mut st = self.shared.lock();
        if !st.receiver_alive {
            return false;
        }
        st.queue.push_back(item);
        drop(st);
        self.shared.available.notify_one();
        true
    }

    /// Queue a whole batch in one lock round, draining `batch` (the
    /// caller keeps the emptied buffer for reuse); `false` when the
    /// receiver is gone (the batch is dropped).
    pub fn send_batch(&self, batch: &mut Vec<T>) -> bool {
        if batch.is_empty() {
            return true;
        }
        let mut st = self.shared.lock();
        if !st.receiver_alive {
            batch.clear();
            return false;
        }
        st.queue.extend(batch.drain(..));
        drop(st);
        self.shared.available.notify_one();
        true
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Sender<T> {
        self.shared.lock().senders += 1;
        Sender {
            shared: Arc::clone(&self.shared),
        }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        st.senders -= 1;
        let last = st.senders == 0;
        drop(st);
        if last {
            // Wake a blocked receiver so it observes the close.
            self.shared.available.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Block until items are queued, then drain *all* of them into
    /// `out` (appended).  Returns the number drained; 0 means every
    /// sender is gone and the queue is empty (channel closed).
    pub fn recv_batch(&self, out: &mut Vec<T>) -> usize {
        let mut st = self.shared.lock();
        loop {
            if !st.queue.is_empty() {
                let n = st.queue.len();
                out.extend(st.queue.drain(..));
                return n;
            }
            if st.senders == 0 {
                return 0;
            }
            st = self
                .shared
                .available
                .wait(st)
                .unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Drain whatever is queued right now without blocking (appended to
    /// `out`); returns the number drained.
    pub fn try_recv_batch(&self, out: &mut Vec<T>) -> usize {
        let mut st = self.shared.lock();
        let n = st.queue.len();
        out.extend(st.queue.drain(..));
        n
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        self.shared.lock().receiver_alive = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn batch_roundtrip_single_thread() {
        let (tx, rx) = channel::<u32>();
        let mut batch = vec![1, 2, 3];
        assert!(tx.send_batch(&mut batch));
        assert!(batch.is_empty(), "send_batch drains the caller's buffer");
        assert!(tx.send(4));
        let mut out = Vec::new();
        assert_eq!(rx.try_recv_batch(&mut out), 4);
        assert_eq!(out, vec![1, 2, 3, 4]);
        assert_eq!(rx.try_recv_batch(&mut out), 0);
    }

    #[test]
    fn recv_blocks_until_sender_publishes() {
        let (tx, rx) = channel::<u64>();
        let sender = thread::spawn(move || {
            let mut b = vec![7, 8];
            assert!(tx.send_batch(&mut b));
        });
        let mut out = Vec::new();
        assert_eq!(rx.recv_batch(&mut out), 2);
        assert_eq!(out, vec![7, 8]);
        sender.join().unwrap();
        // All senders gone + empty queue = closed.
        assert_eq!(rx.recv_batch(&mut out), 0);
    }

    #[test]
    fn close_on_last_sender_drop_wakes_receiver() {
        let (tx, rx) = channel::<u8>();
        let tx2 = tx.clone();
        drop(tx);
        let closer = thread::spawn(move || {
            drop(tx2);
        });
        let mut out = Vec::new();
        assert_eq!(rx.recv_batch(&mut out), 0);
        closer.join().unwrap();
    }

    #[test]
    fn send_to_dropped_receiver_reports_false() {
        let (tx, rx) = channel::<u8>();
        drop(rx);
        assert!(!tx.send(1));
        let mut b = vec![2, 3];
        assert!(!tx.send_batch(&mut b));
        assert!(b.is_empty());
    }

    #[test]
    fn cross_thread_order_is_preserved_per_sender() {
        let (tx, rx) = channel::<u64>();
        let producer = thread::spawn(move || {
            for chunk in 0..100u64 {
                let mut b = (chunk * 10..chunk * 10 + 10).collect();
                assert!(tx.send_batch(&mut b));
            }
        });
        let mut got = Vec::new();
        let mut buf = Vec::new();
        loop {
            buf.clear();
            if rx.recv_batch(&mut buf) == 0 {
                break;
            }
            got.extend_from_slice(&buf);
        }
        producer.join().unwrap();
        let want: Vec<u64> = (0..1000).collect();
        assert_eq!(got, want);
    }
}
