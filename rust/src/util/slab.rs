//! Dependency-free slab arena with generation-stamped slots
//! (DESIGN.md §4.13).
//!
//! The serve hot path parks `Batch` payloads here between EDF push and
//! dispatch pop: `insert` pops the free list and `remove` returns the
//! slot to it, so steady-state serving recycles slots instead of
//! allocating.  Every removal bumps the slot's generation, which makes a
//! retained [`SlabKey`] *stale* rather than dangling — `get`/`remove`
//! with an outdated generation return `None`, mirroring the event
//! calendar's lazy-invalidation discipline (and the daemon's tombstoned
//! tenant slots, which a slab slot must never be confused with: keys are
//! per-entry, slots are per-tenant).

/// `Copy` handle into a [`Slab`]: slot index plus the generation the
/// slot carried at insertion.  Ordering is derived only so keys can ride
/// inside ordered tuples (heap entries); the order itself is meaningless.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SlabKey {
    index: u32,
    generation: u32,
}

struct Slot<T> {
    generation: u32,
    val: Option<T>,
}

/// Vec-backed arena with an explicit free list: O(1) insert/remove and
/// zero heap traffic once the high-water mark is reached.
pub struct Slab<T> {
    slots: Vec<Slot<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab {
            slots: Vec::new(),
            free: Vec::new(),
            len: 0,
        }
    }

    /// Pre-size for `n` resident entries (hot paths size this from the
    /// tenant count so warm-up never reallocates the slot table).
    pub fn with_capacity(n: usize) -> Slab<T> {
        Slab {
            slots: Vec::with_capacity(n),
            free: Vec::with_capacity(n),
            len: 0,
        }
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Store `val`, recycling a freed slot when one exists.
    pub fn insert(&mut self, val: T) -> SlabKey {
        self.len += 1;
        match self.free.pop() {
            Some(i) => {
                let slot = &mut self.slots[i as usize];
                debug_assert!(slot.val.is_none(), "free-listed slot occupied");
                slot.val = Some(val);
                SlabKey {
                    index: i,
                    generation: slot.generation,
                }
            }
            None => {
                let i = u32::try_from(self.slots.len()).expect("slab capacity exceeds u32");
                self.slots.push(Slot {
                    generation: 0,
                    val: Some(val),
                });
                SlabKey {
                    index: i,
                    generation: 0,
                }
            }
        }
    }

    /// Borrow the live entry behind `key`; `None` when the key is stale
    /// (slot since recycled) or was never valid.
    pub fn get(&self, key: SlabKey) -> Option<&T> {
        let slot = self.slots.get(key.index as usize)?;
        if slot.generation != key.generation {
            return None;
        }
        slot.val.as_ref()
    }

    pub fn get_mut(&mut self, key: SlabKey) -> Option<&mut T> {
        let slot = self.slots.get_mut(key.index as usize)?;
        if slot.generation != key.generation {
            return None;
        }
        slot.val.as_mut()
    }

    /// Take the entry out and return its slot to the free list, bumping
    /// the generation so every outstanding key for it goes stale.
    pub fn remove(&mut self, key: SlabKey) -> Option<T> {
        let slot = self.slots.get_mut(key.index as usize)?;
        if slot.generation != key.generation || slot.val.is_none() {
            return None;
        }
        let val = slot.val.take();
        slot.generation = slot.generation.wrapping_add(1);
        self.free.push(key.index);
        self.len -= 1;
        val
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<String> = Slab::new();
        let a = s.insert("a".into());
        let b = s.insert("b".into());
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a).map(String::as_str), Some("a"));
        assert_eq!(s.get(b).map(String::as_str), Some("b"));
        assert_eq!(s.remove(a).as_deref(), Some("a"));
        assert_eq!(s.len(), 1);
        assert!(s.get(a).is_none());
        assert!(s.remove(a).is_none(), "double remove must be None");
    }

    #[test]
    fn recycled_slot_goes_stale_for_old_keys() {
        let mut s: Slab<u64> = Slab::new();
        let first = s.insert(1);
        s.remove(first);
        // The freed slot is reused, but under a bumped generation: the
        // old key must not alias the new payload.
        let second = s.insert(2);
        assert_eq!(s.get(second), Some(&2));
        assert!(s.get(first).is_none());
        assert!(s.remove(first).is_none());
        assert_eq!(s.remove(second), Some(2));
    }

    #[test]
    fn steady_state_reuses_slots_without_growing() {
        let mut s: Slab<usize> = Slab::with_capacity(4);
        let keys: Vec<SlabKey> = (0..4).map(|i| s.insert(i)).collect();
        for k in keys {
            s.remove(k);
        }
        // Churn through many more entries than slots: the table must
        // stay at its high-water mark.
        for round in 0..100 {
            let k = s.insert(round);
            assert_eq!(s.remove(k), Some(round));
        }
        assert_eq!(s.slots.len(), 4);
        assert!(s.is_empty());
    }

    #[test]
    fn get_mut_edits_in_place() {
        let mut s: Slab<Vec<u32>> = Slab::new();
        let k = s.insert(vec![1]);
        s.get_mut(k).unwrap().push(2);
        assert_eq!(s.remove(k), Some(vec![1, 2]));
    }
}
