//! Tiny CLI argument parser (clap substitute for the offline build).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`,
//! optional-value options (`[PLACEHOLDER]` spec: value may be omitted, in
//! which case the key parses as a flag — `--pool` vs `--pool dpu-int8`),
//! repeatable options (`get_all` returns every occurrence in argv order;
//! `get` keeps last-wins semantics), and positional arguments, with
//! generated usage text.  Only what the `mpai` binary and examples need —
//! deliberately no derive magic.

use std::collections::BTreeMap;

/// Parsed arguments: options, flags, and positionals after the subcommand.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    /// Every valued occurrence in argv order (repeatable options).
    multi: Vec<(String, String)>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub enum CliError {
    UnknownOption(String),
    MissingValue(String),
    BadValue {
        key: String,
        value: String,
        hint: String,
    },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::UnknownOption(k) => write!(f, "unknown option --{k}"),
            CliError::MissingValue(k) => write!(f, "option --{k} requires a value"),
            CliError::BadValue { key, value, hint } => {
                write!(f, "invalid value for --{key}: {value:?} ({hint})")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// Declarative spec used for parsing + usage text.
pub struct Spec {
    pub name: &'static str,
    pub about: &'static str,
    /// (key, value placeholder or "" for flags, help)
    pub options: Vec<(&'static str, &'static str, &'static str)>,
}

impl Spec {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for (k, v, help) in &self.options {
            let left = if v.is_empty() {
                format!("--{k}")
            } else if v.starts_with('[') {
                format!("--{k} {v}")
            } else {
                format!("--{k} <{v}>")
            };
            s.push_str(&format!("  {left:<28} {help}\n"));
        }
        s
    }

    /// Parse argv (without the program name / subcommand prefix).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let known_flags: Vec<&str> = self
            .options
            .iter()
            .filter(|(_, v, _)| v.is_empty())
            .map(|(k, _, _)| *k)
            .collect();
        let known_optional: Vec<&str> = self
            .options
            .iter()
            .filter(|(_, v, _)| v.starts_with('['))
            .map(|(k, _, _)| *k)
            .collect();
        let known_opts: Vec<&str> = self
            .options
            .iter()
            .filter(|(_, v, _)| !v.is_empty() && !v.starts_with('['))
            .map(|(k, _, _)| *k)
            .collect();

        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                if known_flags.contains(&key.as_str()) {
                    out.flags.push(key);
                } else if known_optional.contains(&key.as_str()) {
                    // Value may be omitted: `--pool --partition auto` reads
                    // the key as a bare flag; `--pool dpu-int8,mpai` (or the
                    // `=` form) as a valued option.
                    match inline_val {
                        Some(v) => {
                            out.multi.push((key.clone(), v.clone()));
                            out.opts.insert(key, v);
                        }
                        None => match argv.get(i + 1) {
                            Some(next) if !next.starts_with("--") => {
                                i += 1;
                                out.multi.push((key.clone(), next.clone()));
                                out.opts.insert(key, next.clone());
                            }
                            _ => out.flags.push(key),
                        },
                    }
                } else if known_opts.contains(&key.as_str()) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    out.multi.push((key.clone(), val.clone()));
                    out.opts.insert(key, val);
                } else {
                    return Err(CliError::UnknownOption(key));
                }
            } else {
                out.positional.push(arg.clone());
            }
            i += 1;
        }
        Ok(out)
    }
}

impl Args {
    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(String::as_str)
    }

    /// Every value given for a repeatable option, in argv order (empty
    /// when the option never appeared).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.multi
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                value: v.into(),
                hint: "expected unsigned integer".into(),
            }),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                value: v.into(),
                hint: "expected number".into(),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec {
            name: "test",
            about: "test tool",
            options: vec![
                ("count", "N", "how many"),
                ("rate", "HZ", "frame rate"),
                ("verbose", "", "chatty"),
                ("out", "PATH", "output"),
                ("pool", "[MODES]", "optional-value"),
            ],
        }
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_options_and_flags() {
        let a = spec().parse(&sv(&["--count", "5", "--verbose", "pos1"])).unwrap();
        assert_eq!(a.get("count"), Some("5"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn parses_equals_form() {
        let a = spec().parse(&sv(&["--rate=30.5"])).unwrap();
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 30.5);
    }

    #[test]
    fn rejects_unknown() {
        assert!(matches!(
            spec().parse(&sv(&["--bogus"])),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn rejects_missing_value() {
        assert!(matches!(
            spec().parse(&sv(&["--count"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&sv(&[])).unwrap();
        assert_eq!(a.get_usize("count", 7).unwrap(), 7);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn bad_numeric_value_errors() {
        let a = spec().parse(&sv(&["--count", "x"])).unwrap();
        assert!(a.get_usize("count", 0).is_err());
    }

    #[test]
    fn usage_mentions_all_options() {
        let u = spec().usage();
        for k in ["count", "rate", "verbose", "out", "pool"] {
            assert!(u.contains(k));
        }
    }

    #[test]
    fn optional_value_takes_a_value_when_present() {
        let a = spec().parse(&sv(&["--pool", "dpu-int8,mpai"])).unwrap();
        assert_eq!(a.get("pool"), Some("dpu-int8,mpai"));
        assert!(!a.flag("pool"));
        let a = spec().parse(&sv(&["--pool=mpai"])).unwrap();
        assert_eq!(a.get("pool"), Some("mpai"));
    }

    #[test]
    fn repeatable_options_accumulate_in_order() {
        let a = spec()
            .parse(&sv(&["--out", "a", "--count", "1", "--out=b", "--out", "c"]))
            .unwrap();
        assert_eq!(a.get_all("out"), vec!["a", "b", "c"]);
        // `get` keeps last-wins semantics for non-repeatable callers.
        assert_eq!(a.get("out"), Some("c"));
        assert!(a.get_all("rate").is_empty());
    }

    #[test]
    fn optional_value_degrades_to_flag() {
        // Followed by another option: the value is omitted.
        let a = spec().parse(&sv(&["--pool", "--count", "3"])).unwrap();
        assert!(a.flag("pool"));
        assert_eq!(a.get("pool"), None);
        assert_eq!(a.get_usize("count", 0).unwrap(), 3);
        // At the end of argv.
        let a = spec().parse(&sv(&["--verbose", "--pool"])).unwrap();
        assert!(a.flag("pool") && a.flag("verbose"));
    }
}
