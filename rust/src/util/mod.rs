//! Infrastructure substrates built in-repo (the offline environment carries
//! no serde/clap/criterion/proptest — DESIGN.md §4.12).

pub mod benchio;
pub mod cli;
pub mod hash;
pub mod json;
pub mod mpt;
pub mod prng;
pub mod ring;
pub mod slab;
pub mod stats;

/// Format a byte count human-readably (telemetry, artifact inspection).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = n as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

/// Format seconds as an adaptive duration string (ns/µs/ms/s).
pub fn human_seconds(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.00 MiB");
    }

    #[test]
    fn human_seconds_units() {
        assert_eq!(human_seconds(2e-9), "2.0 ns");
        assert_eq!(human_seconds(5e-6), "5.00 µs");
        assert_eq!(human_seconds(0.0042), "4.20 ms");
        assert_eq!(human_seconds(2.5), "2.500 s");
    }
}
