//! Deterministic PRNG (xoshiro256**) — the randomness substrate.
//!
//! No `rand` crate offline; the coordinator's workload generators, the
//! property-testing kit, and the benches all draw from this.  xoshiro256**
//! is tiny, fast, and has no pathological low-bit structure (unlike the
//! xorshift family it replaces).

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed deterministically; any u64 works, including 0.
    pub fn new(seed: u64) -> Prng {
        // SplitMix64 to spread the seed over the state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform f64 in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n) (n > 0). Lemire-style rejection-free enough
    /// for simulation purposes (modulo bias < 2^-32 for n << 2^64).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with the given rate (inter-arrival times in the camera /
    /// request generators).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_reasonable() {
        let mut r = Prng::new(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_covers_all_buckets() {
        let mut r = Prng::new(9);
        let mut seen = [0usize; 10];
        for _ in 0..10_000 {
            seen[r.below(10)] += 1;
        }
        for (i, &c) in seen.iter().enumerate() {
            assert!(c > 700, "bucket {i} count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean_matches_rate() {
        let mut r = Prng::new(13);
        let rate = 4.0;
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(xs, (0..100).collect::<Vec<u32>>()); // astronomically unlikely
    }
}
