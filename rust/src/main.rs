//! `mpai` — CLI for the MPAI co-processing reproduction.
//!
//! Subcommands:
//!   fig2      reproduce Fig. 2 (accelerator throughput survey)
//!   table1    reproduce Table I (pose-estimation accuracy + latency)
//!   serve     run the end-to-end coordinator on the synthetic camera
//!   daemon    long-horizon serve loop with live tenant churn + trace replay
//!   policy    speed–accuracy–energy accelerator selection
//!   inspect   model-zoo graph summaries
//!   cuts      enumerate MPAI partition cut-points for a model
//!   manifest  stamp / verify checksummed compact manifests

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use mpai::accel::interconnect::{links, Link};
use mpai::accel::{deployed_latency, partition_latency, Accelerator, Cpu, Dpu, Tpu, Vpu};
use mpai::coordinator::{
    self, parse_campaign_file, parse_tenant_file, parse_trace_file, ArrivalPattern, CampaignSpec,
    ChurnEvent, ClusterSpec, Config, Constraints, DaemonSpec, DriftSpec, EngineBuilder,
    EventQueueKind, ExecutorKind, FaultSpec, Mode, Objective, PartitionSpec, PowerSchedule,
    RecalSpec, TenantTrace, WindowRecord, Workload,
};
use mpai::net::compiler::{compile, enumerate_cuts, select_cut, Partition};
use mpai::net::models;
use mpai::pose::EvalSet;
use mpai::runtime::{CompactManifest, Manifest};
use mpai::util::cli::{Args, Spec};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: Vec<String>) -> Result<()> {
    let Some(cmd) = args.first().cloned() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "fig2" => cmd_fig2(),
        "table1" => cmd_table1(rest),
        "serve" => cmd_serve(rest),
        "daemon" => cmd_daemon(rest),
        "policy" => cmd_policy(rest),
        "inspect" => cmd_inspect(rest),
        "cuts" => cmd_cuts(rest),
        "manifest" => cmd_manifest(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other:?} (try `mpai help`)"),
    }
}

fn print_usage() {
    println!(
        "mpai — MPSoC + AI-accelerator co-processing (ICECS'24 reproduction)\n\n\
         commands:\n  \
         fig2                         Fig. 2: TPU vs VPU throughput survey\n  \
         table1 [--artifacts DIR]     Table I: accuracy (measured) + latency (modeled)\n  \
         serve  [--mode M|--pool [M,..]] [--sim] [--partition auto] [--nodes N] [--workload SPEC ..] [--executor sim|threaded] run the coordinator\n  \
         daemon --sim [--trace FILE|--workload SPEC ..] [--pattern SPEC] [--churn SPEC ..] [--nodes N] long-horizon serve with live tenant churn\n  \
         policy [--max-ms X] [...]    accelerator selection under constraints\n  \
         inspect [--model NAME]       model-zoo graph summaries\n  \
         cuts   [--model NAME]        enumerate MPAI partition cut-points\n  \
         manifest stamp|verify [--manifest PATH] [FILE ..]  checksummed compact manifests"
    );
}

/// Parse the `--max-*` constraint options shared by `serve` and `policy`.
fn parse_constraints(a: &Args) -> Result<Constraints> {
    let opt = |k: &str| -> Result<Option<f64>> {
        Ok(match a.get(k) {
            Some(_) => Some(a.get_f64(k, 0.0)?),
            None => None,
        })
    };
    Ok(Constraints {
        max_total_ms: opt("max-ms")?,
        max_loce_m: opt("max-loce")?,
        max_orie_deg: opt("max-orie")?,
        max_energy_j: opt("max-energy")?,
    })
}

// ---------------------------------------------------------------------------
// shared engine options (serve + daemon)
// ---------------------------------------------------------------------------

/// Spec rows for the engine-composition options `serve` and `daemon`
/// share — one list, so `--executor`, `--time-scale`, `--events`,
/// `--no-plan-cache`, `--nodes`, … parse identically in both.
fn engine_options() -> Vec<(&'static str, &'static str, &'static str)> {
    vec![
        ("pool", "[MODES]", "multi-backend pool; bare flag = dpu-int8,vpu-fp16"),
        ("partition", "SPEC", "auto | accel@layer,..,accel — N-stage pipelined split (sim)"),
        ("nodes", "N", "cluster serve over N engine nodes (sim)"),
        (
            "node-pool",
            "SPEC",
            "';'-separated per-node pools, cycled: class (dpu-heavy|vpu-heavy|tpu-heavy|mixed) or mode list",
        ),
        (
            "kill-node",
            "SPEC",
            "repeatable: IDX@SECONDS — node fault injection (needs --nodes; deprecated spelling of --storm nodeIDX@T)",
        ),
        ("link", "NAME", "boundary link: usb3|usb2|axi-hp|pcie-x1|csi2 (default usb3)"),
        ("executor", "KIND", "sim (deterministic replay) | threaded (wall-clock workers)"),
        ("time-scale", "X", "threaded: wall seconds per virtual second (default 0.01)"),
        ("events", "KIND", "admission event queue: sharded | calendar | scan (default sharded)"),
        ("sim", "", "simulated backends (no artifacts / PJRT binding needed)"),
        (
            "no-plan-cache",
            "",
            "bypass the content-addressed plan cache (fresh partition sweep per request)",
        ),
        (
            "fail-every",
            "N",
            "inject a fault every Nth infer on the first backend (sim; deprecated — prefer --storm)",
        ),
        (
            "storm",
            "SPEC",
            "repeatable: TARGET[+TARGET..]@T[:recover=S] — correlated fault storm over substrates/modes/nodeN (sim)",
        ),
        ("power", "SPEC", "eclipse power budget: T=W[,T=W..] or a bare wattage W (sim)"),
        (
            "recal",
            "[SPEC]",
            "online recalibration: bare flag or `on` = defaults, else alpha=A[,threshold=T]",
        ),
        (
            "drift",
            "SPEC",
            "repeatable: SUBSTRATE[:rate=R][,cap=C] — per-call service-time drift (sim)",
        ),
        (
            "campaign",
            "FILE",
            "JSON space-environment campaign: {\"storms\":[..], \"power\":\"..\", \"recal\":\"..\", \"drift\":[..]}",
        ),
        ("timeout-ms", "MS", "batcher timeout (default 50)"),
        ("max-ms", "X", "constraint: max modeled total latency (ms)"),
        ("max-loce", "X", "constraint: max localization error (m)"),
        ("max-orie", "X", "constraint: max orientation error (deg)"),
        ("max-energy", "X", "constraint: max energy per frame (J)"),
    ]
}

/// Engine-composition options parsed from the shared [`engine_options`]
/// rows: everything that decides *what serves* (pool/partition/cluster,
/// executor, event queue, plan cache, faults), as opposed to what is
/// served (workloads, traces, frames — per-command).
struct EngineArgs {
    pool: Vec<Mode>,
    partition: Option<PartitionSpec>,
    cluster: Option<ClusterSpec>,
    boundary_link: Link,
    fail_every: Option<usize>,
    campaign: CampaignSpec,
    executor: ExecutorKind,
    time_scale: f64,
    events: EventQueueKind,
    plan_cache: bool,
    sim: bool,
    batch_timeout: Duration,
    constraints: Constraints,
}

impl EngineArgs {
    /// `default_pool` differs per command: `serve` defaults to the single
    /// `--mode` (empty pool), `daemon` to the canonical MPAI pair.
    fn parse(a: &Args, default_pool: &[Mode]) -> Result<EngineArgs> {
        let pool = if a.flag("pool") {
            // Bare `--pool`: the canonical MPAI pair.
            vec![Mode::DpuInt8, Mode::VpuFp16]
        } else {
            match a.get("pool") {
                None => default_pool.to_vec(),
                Some(list) => list
                    .split(',')
                    .map(|m| {
                        Mode::from_label(m.trim())
                            .with_context(|| format!("bad mode {m:?} in --pool (see `mpai help`)"))
                    })
                    .collect::<Result<Vec<Mode>>>()?,
            }
        };
        let partition = match a.get("partition") {
            None => None,
            Some(s) => Some(PartitionSpec::parse(s).map_err(|e| anyhow!("bad --partition: {e}"))?),
        };
        let cluster = match a.get("nodes") {
            None => {
                if a.get("node-pool").is_some() || !a.get_all("kill-node").is_empty() {
                    bail!("--node-pool/--kill-node need --nodes N");
                }
                None
            }
            Some(_) => {
                let n = a.get_usize("nodes", 0)?;
                Some(ClusterSpec::from_cli(n, a.get("node-pool"), &a.get_all("kill-node"))?)
            }
        };
        let boundary_link = match a.get("link") {
            None => links::USB3,
            Some(n) => links::by_name(n)
                .with_context(|| format!("bad --link {n:?} (usb3|usb2|axi-hp|pcie-x1|csi2)"))?,
        };
        let fail_every = match a.get("fail-every") {
            Some(_) => Some(a.get_usize("fail-every", 0)?),
            None => None,
        };
        // The space-environment campaign: a JSON file sets the base, then
        // the per-axis CLI options layer on (storms/drifts append, power
        // and recal replace).
        let mut campaign = match a.get("campaign") {
            None => CampaignSpec::default(),
            Some(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading --campaign file {path:?}"))?;
                parse_campaign_file(&text).map_err(|e| anyhow!("bad --campaign {path:?}: {e}"))?
            }
        };
        for s in a.get_all("storm") {
            campaign
                .faults
                .extend(FaultSpec::parse(s).map_err(|e| anyhow!("bad --storm: {e}"))?);
        }
        if let Some(s) = a.get("power") {
            campaign.power = PowerSchedule::parse(s).map_err(|e| anyhow!("bad --power: {e}"))?;
        }
        if let Some(s) = a.get("recal") {
            campaign.recal = Some(RecalSpec::parse(s).map_err(|e| anyhow!("bad --recal: {e}"))?);
        } else if a.flag("recal") {
            campaign.recal = Some(RecalSpec::default());
        }
        for s in a.get_all("drift") {
            campaign
                .drift
                .push(DriftSpec::parse(s).map_err(|e| anyhow!("bad --drift: {e}"))?);
        }
        if cluster.is_none() && !campaign.node_faults().is_empty() {
            bail!("--storm nodeIDX@T needs --nodes N");
        }
        let executor = ExecutorKind::parse(a.get_or("executor", "sim"))
            .context("bad --executor (sim | threaded)")?;
        let events = EventQueueKind::parse(a.get_or("events", "sharded"))
            .context("bad --events (sharded | calendar | scan)")?;
        Ok(EngineArgs {
            pool,
            partition,
            cluster,
            boundary_link,
            fail_every,
            campaign,
            executor,
            time_scale: a.get_f64("time-scale", 0.01)?,
            events,
            plan_cache: !a.flag("no-plan-cache"),
            sim: a.flag("sim"),
            batch_timeout: Duration::from_millis(a.get_usize("timeout-ms", 50)? as u64),
            constraints: parse_constraints(a)?,
        })
    }

    /// Base config for these engine options; per-command fields (mode,
    /// frames, workloads, artifacts dir, …) layer on via struct update.
    fn config(&self) -> Config {
        Config {
            batch_timeout: self.batch_timeout,
            pool: self.pool.clone(),
            sim: self.sim,
            fail_every: self.fail_every,
            campaign: self.campaign.clone(),
            constraints: self.constraints,
            partition: self.partition.clone(),
            boundary_link: self.boundary_link,
            executor: self.executor,
            time_scale: self.time_scale,
            events: self.events,
            plan_cache: self.plan_cache,
            ..Default::default()
        }
    }

    /// Builder over this engine composition (attaches the cluster spec).
    fn builder<'e>(&self, cfg: &Config) -> EngineBuilder<'e> {
        let b = EngineBuilder::new(cfg);
        match &self.cluster {
            Some(spec) => b.cluster(spec.clone()),
            None => b,
        }
    }

    /// Human-readable engine summary fragments for the banner line.
    fn describe(&self) -> String {
        let split = match &self.partition {
            Some(PartitionSpec::Auto) => " partition auto".to_string(),
            Some(PartitionSpec::Manual(stages)) => format!(
                " partition {}",
                stages.iter().map(|s| s.accel.as_str()).collect::<Vec<_>>().join("|")
            ),
            None => String::new(),
        };
        let nodes = match &self.cluster {
            Some(c) if c.kills.is_empty() => format!(" nodes {}", c.nodes.len()),
            Some(c) => format!(" nodes {} ({} kill(s))", c.nodes.len(), c.kills.len()),
            None => String::new(),
        };
        let campaign = if self.campaign.is_empty() {
            String::new()
        } else {
            let c = &self.campaign;
            let mut axes = Vec::new();
            if !c.faults.is_empty() {
                axes.push(format!("{} storm window(s)", c.faults.len()));
            }
            if !c.power.is_empty() {
                axes.push(format!("{} power window(s)", c.power.windows().len()));
            }
            if c.recal.is_some() {
                axes.push("recal".to_string());
            }
            if !c.drift.is_empty() {
                axes.push(format!("{} drift(s)", c.drift.len()));
            }
            format!(" campaign [{}]", axes.join(", "))
        };
        format!("{split}{nodes}{campaign}")
    }
}

// ---------------------------------------------------------------------------
// fig2
// ---------------------------------------------------------------------------

fn cmd_fig2() -> Result<()> {
    println!("Fig. 2 — inference throughput of AI accelerators (modeled)\n");
    println!(
        "{:<14} {:>12} {:>12} {:>12} {:>14}",
        "network", "TPU FPS", "VPU FPS", "DPU FPS", "TPU/VPU ratio"
    );
    for g in models::fig2_models() {
        let tpu = deployed_latency(&Tpu, &g).fps();
        let vpu = deployed_latency(&Vpu, &g).fps();
        let dpu = deployed_latency(&Dpu, &g).fps();
        println!(
            "{:<14} {:>12.1} {:>12.1} {:>12.1} {:>13.2}x",
            g.name, tpu, vpu, dpu, tpu / vpu
        );
    }
    println!(
        "\npaper shape: MobileNetV2 TPU ~8x VPU; ResNet-50 VPU ~2x TPU; \
         Inception-V4 both ~10 FPS"
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// table1
// ---------------------------------------------------------------------------

fn cmd_table1(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "mpai table1",
        about: "reproduce Table I",
        options: vec![
            ("artifacts", "DIR", "artifacts directory (default artifacts)"),
            ("frames", "N", "eval frames to run (default: whole eval set)"),
        ],
    };
    let a = spec.parse(argv)?;
    let dir = PathBuf::from(a.get_or("artifacts", "artifacts"));
    let manifest = Manifest::load(&dir)?;
    let eval = Arc::new(EvalSet::load(&manifest.eval_file)?);
    let frames = a.get_usize("frames", eval.len())?;

    println!("Table I — satellite pose estimation ({} eval frames)\n", frames);
    println!(
        "{:<10} {:>9} {:>9} | {:>10} {:>10} | {:>12} {:>10} {:>12}",
        "mode", "LOCE m", "ORIE deg", "inf ms*", "total ms*", "host inf ms", "energy J*", "device"
    );

    let profiles = coordinator::profile_modes(&manifest);
    for mode in Mode::ALL {
        let (loce, orie, host_ms) = measure_mode(&manifest, eval.clone(), mode, frames)?;
        let p = profiles[&mode];
        let device = match mode {
            Mode::CpuFp32 => "DevBoard",
            Mode::CpuFp16 | Mode::DpuInt8 => "ZCU104",
            Mode::VpuFp16 => "NCS2",
            Mode::TpuInt8 => "DevBoard",
            Mode::Mpai => "ZCU104+NCS2",
        };
        println!(
            "{:<10} {:>9.3} {:>9.2} | {:>10.1} {:>10.1} | {:>12.2} {:>10.2} {:>12}",
            mode.label(), loce, orie, p.inference_ms, p.total_ms, host_ms, p.energy_j, device
        );
    }
    println!(
        "\n* modeled at paper scale (full-size UrsoNet on the accelerator \
         substrates); accuracy is measured by executing the quantized \
         artifacts via PJRT on this testbed's UrsoNet-lite"
    );
    Ok(())
}

/// Run `frames` eval frames through a mode's artifacts; return
/// (LOCE, ORIE, mean host inference ms/frame).
fn measure_mode(
    manifest: &Manifest,
    eval: Arc<EvalSet>,
    mode: Mode,
    frames: usize,
) -> Result<(f64, f64, f64)> {
    let cfg = Config {
        artifacts_dir: manifest.dir.clone(),
        mode: Some(mode),
        batch_timeout: Duration::from_millis(1),
        camera_fps: 1000.0,
        frames: frames as u64,
        ..Default::default()
    };
    let backend = coordinator::PjrtBackend::new(manifest, mode)
        .with_context(|| format!("building backend for {}", mode.label()))?;
    // A pool of one PJRT backend, served through the builder (the legacy
    // `run_with_backend` path, spelled out).
    let (net_h, net_w, _) = manifest.net_input;
    let mut pool = coordinator::Dispatcher::new(manifest.batch, net_h, net_w, cfg.constraints);
    pool.add_backend(Box::new(backend), None);
    let out = EngineBuilder::new(&cfg).engine(&mut pool).eval(eval).build()?.run()?;
    let (loce, orie) = out.telemetry.accuracy();
    let host_ms = out.telemetry.inference_summary().mean() * 1e3;
    Ok((loce, orie, host_ms))
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

fn cmd_serve(argv: &[String]) -> Result<()> {
    let mut options = vec![
        ("artifacts", "DIR", "artifacts directory (default artifacts)"),
        ("mode", "MODE", "cpu-fp32|cpu-fp16|vpu-fp16|tpu-int8|dpu-int8|mpai"),
        (
            "workload",
            "SPEC",
            "repeatable: NAME:net=..,qos=..,deadline_ms=..,rate=.. — multi-tenant serve (sim)",
        ),
        ("tenants", "FILE", "JSON workload list ([{...}] or {\"workloads\": [...]})"),
        ("fps", "HZ", "camera frame rate (default 10)"),
        ("frames", "N", "frames to process (default 64)"),
        ("csv", "PATH", "write per-frame telemetry CSV"),
    ];
    options.extend(engine_options());
    let spec = Spec {
        name: "mpai serve",
        about: "run the end-to-end coordinator",
        options,
    };
    let a = spec.parse(argv)?;
    let eng = EngineArgs::parse(&a, &[])?;
    let mode = Mode::from_label(a.get_or("mode", "mpai"))
        .context("bad --mode (see `mpai help`)")?;
    let mut workloads: Vec<Workload> = Vec::new();
    if let Some(path) = a.get("tenants") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --tenants file {path:?}"))?;
        workloads.extend(
            parse_tenant_file(&text).map_err(|e| anyhow!("bad --tenants {path:?}: {e}"))?,
        );
    }
    for spec in a.get_all("workload") {
        workloads.push(Workload::parse(spec).map_err(|e| anyhow!("bad --workload: {e}"))?);
    }
    let cfg = Config {
        artifacts_dir: PathBuf::from(a.get_or("artifacts", "artifacts")),
        mode: Some(mode),
        camera_fps: a.get_f64("fps", 10.0)?,
        frames: a.get_usize("frames", 64)? as u64,
        workloads,
        ..eng.config()
    };
    let engaged = if eng.pool.is_empty() {
        format!("mode {}", mode.label())
    } else {
        format!(
            "pool [{}]",
            eng.pool.iter().map(|m| m.label()).collect::<Vec<_>>().join(", ")
        )
    };
    let tenants_note = if cfg.workloads.is_empty() {
        String::new()
    } else {
        format!(
            " tenants [{}]",
            cfg.workloads
                .iter()
                .map(|w| format!("{} ({})", w.name, w.qos.label()))
                .collect::<Vec<_>>()
                .join(", ")
        )
    };
    println!(
        "mpai serve — {engaged}{}{tenants_note} fps {} frames {} executor {}{}",
        eng.describe(),
        cfg.camera_fps,
        cfg.frames,
        cfg.executor.label(),
        if cfg.sim { " (simulated backends)" } else { "" }
    );
    let out = eng.builder(&cfg).build()?.run()?;
    println!("{}", out.telemetry.report());
    if let Some(path) = a.get("csv") {
        std::fs::write(path, out.telemetry.to_csv())?;
        println!("telemetry csv -> {path}");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// daemon
// ---------------------------------------------------------------------------

fn cmd_daemon(argv: &[String]) -> Result<()> {
    let mut options = vec![
        (
            "trace",
            "FILE",
            "JSON trace: tenants with arrival patterns + join/rerate/leave lifecycles",
        ),
        (
            "workload",
            "SPEC",
            "repeatable: NAME:net=..,qos=..,deadline_ms=..,rate=..,frames=.. — present-from-start tenant",
        ),
        (
            "pattern",
            "SPEC",
            "arrival pattern for --workload tenants: steady | diurnal,amplitude=..,period_s=.. | bursts,.. | flash,..",
        ),
        (
            "churn",
            "SPEC",
            "repeatable: join@T:WORKLOAD | leave@T:NAME | rerate@T:NAME=RATE (T in seconds)",
        ),
        ("window-s", "S", "steady-state telemetry window (default 10; trace file may set it)"),
        ("windows", "N", "print the first and last N window records (default 3)"),
    ];
    options.extend(engine_options());
    let spec = Spec {
        name: "mpai daemon",
        about: "long-horizon serve loop with live tenant churn and trace replay (sim)",
        options,
    };
    let a = spec.parse(argv)?;
    let eng = EngineArgs::parse(&a, &[Mode::DpuInt8, Mode::VpuFp16])?;

    // Tenant lifecycles: a trace file, plus any --workload steady tenants
    // (with an optional shared --pattern), plus extra --churn events.
    let mut window = None;
    let mut tenants: Vec<TenantTrace> = Vec::new();
    if let Some(path) = a.get("trace") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading --trace file {path:?}"))?;
        let (w, traced) =
            parse_trace_file(&text).map_err(|e| anyhow!("bad --trace {path:?}: {e}"))?;
        window = w;
        tenants.extend(traced);
    }
    let pattern = match a.get("pattern") {
        None => ArrivalPattern::Steady,
        Some(s) => ArrivalPattern::parse(s).map_err(|e| anyhow!("bad --pattern: {e}"))?,
    };
    for spec in a.get_all("workload") {
        let w = Workload::parse(spec).map_err(|e| anyhow!("bad --workload: {e}"))?;
        let mut t = TenantTrace::steady(w);
        t.pattern = pattern.clone();
        tenants.push(t);
    }
    let churn = a
        .get_all("churn")
        .into_iter()
        .map(|s| ChurnEvent::parse(s).map_err(|e| anyhow!("bad --churn: {e}")))
        .collect::<Result<Vec<ChurnEvent>>>()?;
    // Explicit --window-s beats the trace file's window, which beats 10 s.
    let window = match a.get("window-s") {
        Some(_) => {
            let s = a.get_f64("window-s", 10.0)?;
            if !s.is_finite() || s <= 0.0 {
                bail!("bad --window-s {s}: expected a positive number of seconds");
            }
            Duration::from_secs_f64(s)
        }
        None => window.unwrap_or(Duration::from_secs(10)),
    };
    let dspec = DaemonSpec { window, tenants, churn };

    let cfg = eng.config();
    println!(
        "mpai daemon — pool [{}]{} window {:.1} s, {} tenant lifecycle{}, {} churn event{}, executor {}{}",
        eng.pool.iter().map(|m| m.label()).collect::<Vec<_>>().join(", "),
        eng.describe(),
        dspec.window.as_secs_f64(),
        dspec.tenants.len(),
        if dspec.tenants.len() == 1 { "" } else { "s" },
        dspec.churn.len(),
        if dspec.churn.len() == 1 { "" } else { "s" },
        cfg.executor.label(),
        if cfg.sim { " (simulated backends)" } else { "" }
    );

    let out = eng.builder(&cfg).build()?.run_daemon(&dspec)?;
    println!("{}", out.telemetry.report());
    println!(
        "churn: {} join{}, {} leave{}, {} rerate{}",
        out.joins,
        if out.joins == 1 { "" } else { "s" },
        out.leaves,
        if out.leaves == 1 { "" } else { "s" },
        out.rerates,
        if out.rerates == 1 { "" } else { "s" },
    );

    // Windowed steady-state telemetry: the head and tail of the run.
    let show = a.get_usize("windows", 3)?;
    println!("windows: {} materialized", out.windows.len());
    let total = out.windows.len();
    for (i, w) in out.windows.iter().enumerate() {
        if i == show && total > 2 * show {
            println!("  … {} windows elided …", total - 2 * show);
        }
        if i >= show && i < total.saturating_sub(show) {
            continue;
        }
        print_window(w);
    }
    Ok(())
}

fn print_window(w: &WindowRecord) {
    println!("  window {:>4} @ {:>8.1} s", w.index, w.start.as_secs_f64());
    for t in &w.tenants {
        println!(
            "    {:<12} admitted {:>7} completed {:>7} shed {:>6} miss {:>6}  p50 {:>8.2} ms  p99 {:>8.2} ms",
            t.id.name(),
            t.admitted,
            t.completed,
            t.shed,
            t.misses,
            t.p50_ms,
            t.p99_ms
        );
    }
}

// ---------------------------------------------------------------------------
// policy
// ---------------------------------------------------------------------------

fn cmd_policy(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "mpai policy",
        about: "speed–accuracy–energy accelerator selection",
        options: vec![
            ("artifacts", "DIR", "artifacts directory (default artifacts)"),
            ("max-ms", "X", "max total latency"),
            ("max-loce", "X", "max localization error (m)"),
            ("max-orie", "X", "max orientation error (deg)"),
            ("max-energy", "X", "max energy per frame (J)"),
            ("objective", "O", "latency|energy|accuracy (default latency)"),
        ],
    };
    let a = spec.parse(argv)?;
    let manifest = Manifest::load(&PathBuf::from(a.get_or("artifacts", "artifacts")))?;
    let profiles = coordinator::profile_modes(&manifest);

    println!("mode profiles (modeled latency/energy at paper scale, measured accuracy):\n");
    println!(
        "{:<10} {:>10} {:>10} {:>9} {:>9} {:>10}",
        "mode", "inf ms", "total ms", "LOCE m", "ORIE deg", "energy J"
    );
    for p in profiles.values() {
        println!(
            "{:<10} {:>10.1} {:>10.1} {:>9.3} {:>9.2} {:>10.2}",
            p.mode.label(), p.inference_ms, p.total_ms, p.loce_m, p.orie_deg, p.energy_j
        );
    }

    let constraints = parse_constraints(&a)?;
    let objective = match a.get_or("objective", "latency") {
        "latency" => Objective::MinLatency,
        "energy" => Objective::MinEnergy,
        "accuracy" => Objective::MaxAccuracy,
        o => bail!("bad objective {o:?}"),
    };
    match coordinator::select(&profiles, constraints, objective) {
        Some(sel) => println!(
            "\nselected: {} (total {:.1} ms, LOCE {:.3} m, {:.2} J)",
            sel.mode.label(), sel.total_ms, sel.loce_m, sel.energy_j
        ),
        None => println!("\nno mode satisfies the constraints"),
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// inspect / cuts
// ---------------------------------------------------------------------------

fn cmd_inspect(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "mpai inspect",
        about: "model-zoo graph summaries",
        options: vec![("model", "NAME", "one model (default: all)")],
    };
    let a = spec.parse(argv)?;
    let names = match a.get("model") {
        Some(n) => vec![n.to_string()],
        None => vec![
            "mobilenet_v2".into(),
            "resnet50".into(),
            "inception_v4".into(),
            "ursonet_full".into(),
            "ursonet_lite".into(),
        ],
    };
    for n in names {
        let g = models::by_name(&n).with_context(|| format!("unknown model {n:?}"))?;
        println!("{}", g.summary());
        let c = compile(&g);
        println!("  compiled: {} layers (BN folded, activations fused)", c.layers.len());
    }
    Ok(())
}

fn cmd_cuts(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "mpai cuts",
        about: "enumerate MPAI partition cut-points",
        options: vec![
            ("model", "NAME", "model (default ursonet_lite)"),
            ("top", "N", "show N best cuts by modeled latency (default 10)"),
        ],
    };
    let a = spec.parse(argv)?;
    let name = a.get_or("model", "ursonet_lite");
    let g = models::by_name(name).with_context(|| format!("unknown model {name:?}"))?;
    let compiled = compile(&g);
    let top = a.get_usize("top", 10)?;

    let (dpu, vpu) = (Dpu, Vpu);
    let mut accels: BTreeMap<String, &dyn Accelerator> = BTreeMap::new();
    accels.insert("dpu".into(), &dpu);
    accels.insert("vpu".into(), &vpu);

    // The estimate's typed error (`EstimateError`) propagates as a CLI
    // error instead of panicking, and `total_cmp` keeps the sort safe even
    // if a model ever yields a NaN latency.
    let mut rows: Vec<(f64, String, usize, u64, u64)> = Vec::new();
    for c in enumerate_cuts(&compiled, 1) {
        let lat = partition_latency(
            &compiled,
            &Partition::two_way(&compiled, c.at, "dpu", "vpu"),
            &accels,
            &links::USB3,
        )
        .with_context(|| format!("estimating the cut after layer {:?}", c.layer_name))?;
        rows.push((lat.total_ms(), c.layer_name, c.boundary_bytes, c.macs.0, c.macs.1));
    }
    rows.sort_by(|x, y| x.0.total_cmp(&y.0));

    println!(
        "{} DPU->VPU cut-points for {name} (modeled, sorted by latency):\n",
        rows.len()
    );
    println!(
        "{:<24} {:>12} {:>14} {:>12} {:>12}",
        "cut after layer", "latency ms", "boundary B", "head MMACs", "tail MMACs"
    );
    for (ms, layer, bytes, h, t) in rows.into_iter().take(top) {
        println!(
            "{:<24} {:>12.2} {:>14} {:>12.1} {:>12.1}",
            layer, ms, bytes, h as f64 / 1e6, t as f64 / 1e6
        );
    }

    // The automatic selection (`serve --partition auto` uses the same
    // sweep): throughput-optimal, not latency-optimal — pipelining ranks
    // by the bottleneck stage.
    if let Some(sel) = select_cut(&compiled, &dpu, &vpu, &links::USB3, &Constraints::default()) {
        println!(
            "\nauto-selected cut (steady-state throughput argmax): after {} — \
             {:.1} FPS pipelined, {:.2} ms sequential, {:.2} J/frame",
            sel.cut.layer_name,
            sel.steady_fps,
            sel.latency.total_ms(),
            sel.energy_j
        );
    }

    let cpu = Cpu::zcu104();
    println!(
        "\nreference: dpu-only {:.2} ms, vpu-only {:.2} ms, cpu-fp16 {:.2} ms",
        deployed_latency(&Dpu, &g).total_ms(),
        deployed_latency(&Vpu, &g).total_ms(),
        deployed_latency(&cpu, &g).total_ms()
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// manifest
// ---------------------------------------------------------------------------

/// `mpai manifest stamp|verify` — drive the checksummed compact-manifest
/// layer (DESIGN.md §4.10).  `verify` recomputes every entry's sha256;
/// `stamp` (re)checksums the named files (or, with no files, every entry
/// already in the manifest) and rewrites the document.
fn cmd_manifest(argv: &[String]) -> Result<()> {
    let spec = Spec {
        name: "mpai manifest",
        about: "stamp / verify checksummed compact manifests",
        options: vec![
            (
                "manifest",
                "PATH",
                "manifest file (default bench/MANIFEST.json); entry paths are relative to its directory",
            ),
            ("name", "NAME", "manifest name when creating (default: parent directory name)"),
        ],
    };
    let a = spec.parse(argv)?;
    let path = PathBuf::from(a.get_or("manifest", "bench/MANIFEST.json"));
    let root = path
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| PathBuf::from("."));
    let action = a
        .positional
        .first()
        .map(String::as_str)
        .context("missing action: `mpai manifest stamp|verify [FILE ..]`")?;
    match action {
        "verify" => {
            let m = CompactManifest::load(&path)?;
            let n = m.verify(&root)?;
            println!(
                "manifest {path:?}: {n} entr{} verified OK",
                if n == 1 { "y" } else { "ies" }
            );
            Ok(())
        }
        "stamp" => {
            let mut m = if path.exists() {
                CompactManifest::load(&path)?
            } else {
                let name = match a.get("name") {
                    Some(n) => n.to_string(),
                    None => root
                        .file_name()
                        .map(|n| n.to_string_lossy().into_owned())
                        .unwrap_or_else(|| "manifest".to_string()),
                };
                CompactManifest::new(&name)
            };
            let rels: Vec<String> = if a.positional.len() > 1 {
                a.positional[1..].to_vec()
            } else {
                m.entries.keys().cloned().collect()
            };
            if rels.is_empty() {
                bail!("nothing to stamp: pass file paths relative to {root:?}");
            }
            for rel in &rels {
                let e = m.stamp_file(&root, rel)?;
                println!(
                    "stamped {rel} ({}, {} B, sha256 {}…)",
                    e.kind,
                    e.size,
                    &e.sha256[..12]
                );
            }
            m.save(&path)?;
            println!("wrote {path:?} ({} entries)", m.entries.len());
            Ok(())
        }
        other => bail!("unknown manifest action {other:?} (stamp | verify)"),
    }
}
