//! `bench-gate` — CI regression gate over machine-readable bench results.
//!
//! The bench-smoke CI job runs the ablation benches with
//! `MPAI_BENCH_JSON=<dir>`, which makes each bench emit a
//! `BENCH_<name>.json` results document (see `mpai::util::benchio`).
//! This binary compares those results against the committed
//! `bench/baseline.json` and fails (exit 1) on regressions past the
//! baseline's tolerance:
//!
//! ```text
//! bench-gate check   bench/baseline.json <results-dir>
//! bench-gate refresh bench/baseline.json <results-dir>
//! ```
//!
//! Direction is inferred from the metric name: `*_fps` / `*_speedup` /
//! `*_eps` are higher-is-better, `*_s` / `*_ms` are lower-is-better,
//! anything else is gated two-sided.  A baseline value of `null` marks a
//! metric that is tracked but not yet baselined (recorded, never failed);
//! a metric may also be an object `{"value": V, "tolerance_pct": T}` to
//! gate at a per-metric tolerance (wider bands for metrics with host
//! jitter, e.g. normalized wall-replay times) — `refresh` replaces every
//! gated baseline entry with the observed values, preserving per-metric
//! tolerances (the refresh procedure is documented in EXPERIMENTS.md).

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use anyhow::{bail, Context, Result};

use mpai::runtime::CompactManifest;
use mpai::util::json::{self, Json};

const DEFAULT_TOLERANCE_PCT: f64 = 15.0;

/// Which way a metric is allowed to move freely.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Direction {
    HigherIsBetter,
    LowerIsBetter,
    TwoSided,
}

fn direction(metric: &str) -> Direction {
    if metric.ends_with("_fps") || metric.ends_with("_speedup") || metric.ends_with("_eps") {
        Direction::HigherIsBetter
    } else if metric.ends_with("_s") || metric.ends_with("_ms") {
        Direction::LowerIsBetter
    } else {
        Direction::TwoSided
    }
}

/// Gated value of a baseline entry: a bare number, or the `value` field
/// of a `{"value": V, "tolerance_pct": T}` object.  `None` marks a
/// tracked-only (unbaselined) metric.
fn baseline_value(entry: &Json) -> Option<f64> {
    entry
        .as_f64()
        .or_else(|| entry.get("value").and_then(Json::as_f64))
}

/// Per-metric tolerance (fraction), falling back to the file default.
fn baseline_tolerance(entry: &Json, default_tol: f64) -> f64 {
    entry
        .get("tolerance_pct")
        .and_then(Json::as_f64)
        .map(|p| p / 100.0)
        .unwrap_or(default_tol)
}

fn load(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))
}

fn results_path(dir: &Path, bench: &str) -> PathBuf {
    dir.join(format!("BENCH_{bench}.json"))
}

/// Observed metrics of one emitted results document.
fn observed_metrics(doc: &Json) -> Result<Vec<(String, f64)>> {
    let metrics = doc
        .get("metrics")
        .and_then(Json::as_obj)
        .context("results document has no \"metrics\" object")?;
    Ok(metrics
        .iter()
        .filter_map(|(k, v)| v.as_f64().map(|x| (k.clone(), x)))
        .collect())
}

fn check(baseline_path: &Path, results_dir: &Path) -> Result<usize> {
    let baseline = load(baseline_path)?;
    let tolerance_pct = baseline
        .get("tolerance_pct")
        .and_then(Json::as_f64)
        .unwrap_or(DEFAULT_TOLERANCE_PCT);
    let tol = tolerance_pct / 100.0;
    let benches = baseline
        .get("benches")
        .and_then(Json::as_obj)
        .context("baseline has no \"benches\" object")?;

    let mut failures = 0usize;
    for (bench, metrics) in benches {
        let Some(metrics) = metrics.as_obj() else {
            bail!("baseline bench {bench:?} is not an object");
        };
        let gated = metrics.values().any(|v| baseline_value(v).is_some());
        let path = results_path(results_dir, bench);
        let doc = match load(&path) {
            Ok(d) => d,
            // A bench with only tracked (`null`) metrics may legitimately
            // not have run (e.g. a single-bench local check); a *gated*
            // bench that emitted nothing is a hard failure.
            Err(e) if gated => {
                println!("FAIL  {bench}: no results emitted ({e:#})");
                failures += 1;
                continue;
            }
            Err(_) => {
                println!("note  {bench}: no results emitted (all metrics unbaselined) — skipped");
                continue;
            }
        };
        for (metric, entry) in metrics {
            let observed = doc
                .get("metrics")
                .and_then(|m| m.get(metric))
                .and_then(Json::as_f64);
            let Some(observed) = observed else {
                println!("FAIL  {bench}.{metric}: metric missing from {path:?}");
                failures += 1;
                continue;
            };
            let Some(base) = baseline_value(entry) else {
                println!(
                    "note  {bench}.{metric}: observed {observed:.4} (unbaselined — \
                     run `bench-gate refresh` to start gating it)"
                );
                continue;
            };
            if !base.is_finite() || base == 0.0 {
                println!("note  {bench}.{metric}: unusable baseline {base} — skipped");
                continue;
            }
            let tol = baseline_tolerance(entry, tol);
            let delta = (observed - base) / base;
            let regressed = match direction(metric) {
                Direction::HigherIsBetter => delta < -tol,
                Direction::LowerIsBetter => delta > tol,
                Direction::TwoSided => delta.abs() > tol,
            };
            if regressed {
                println!(
                    "FAIL  {bench}.{metric}: {observed:.4} vs baseline {base:.4} \
                     ({:+.1}% > {:.0}% tolerance)",
                    delta * 100.0,
                    tol * 100.0
                );
                failures += 1;
            } else if delta.abs() > tol {
                // Only reachable for one-sided metrics that *improved*
                // past the tolerance: keep the baseline honest.
                println!(
                    "note  {bench}.{metric}: improved {:+.1}% past tolerance — \
                     consider a baseline refresh",
                    delta * 100.0
                );
            } else {
                println!(
                    "ok    {bench}.{metric}: {observed:.4} vs {base:.4} ({:+.1}%)",
                    delta * 100.0
                );
            }
        }
    }
    Ok(failures)
}

/// Rewrite the baseline from observed results.  By default a metric that
/// was `null` (tracked, unbaselined — e.g. machine-dependent wall times)
/// stays `null` and newly-seen metrics enter as `null`; `promote_all`
/// turns every observed value into a gated baseline.  Per-metric
/// tolerance objects keep their `tolerance_pct` across a refresh.
fn refresh(baseline_path: &Path, results_dir: &Path, promote_all: bool) -> Result<()> {
    let old = load(baseline_path).ok();
    let tolerance_pct = old
        .as_ref()
        .and_then(|b| b.get("tolerance_pct").and_then(Json::as_f64))
        .unwrap_or(DEFAULT_TOLERANCE_PCT);
    // The old baseline entry for one bench.metric, if any.
    let old_entry = |bench: &str, metric: &str| -> Option<&Json> {
        old.as_ref()
            .and_then(|b| b.get("benches"))
            .and_then(|bs| bs.get(bench))
            .and_then(|m| m.get(metric))
    };

    let mut benches = Json::obj();
    let mut entries: Vec<PathBuf> = std::fs::read_dir(results_dir)
        .with_context(|| format!("listing {results_dir:?}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                .unwrap_or(false)
        })
        .collect();
    entries.sort();
    if entries.is_empty() {
        bail!("no BENCH_*.json results in {results_dir:?}");
    }
    for path in entries {
        let doc = load(&path)?;
        let name = doc
            .get("name")
            .and_then(Json::as_str)
            .with_context(|| format!("{path:?} has no \"name\""))?
            .to_string();
        let mut metrics = Json::obj();
        for (k, v) in observed_metrics(&doc)? {
            let entry = old_entry(&name, &k);
            let gated = promote_all || entry.is_some_and(|e| baseline_value(e).is_some());
            if !gated {
                // A tracked-only object keeps its shape so a preset
                // tolerance_pct survives until the metric is promoted;
                // bare nulls (and new metrics) stay null.
                match entry {
                    Some(e) if e.get("tolerance_pct").is_some() => metrics.set(&k, e.clone()),
                    _ => metrics.set(&k, Json::Null),
                }
                continue;
            }
            let pct = entry
                .and_then(|e| e.get("tolerance_pct"))
                .and_then(Json::as_f64);
            match pct {
                Some(pct) => {
                    let mut o = Json::obj();
                    o.set("value", Json::Num(v));
                    o.set("tolerance_pct", Json::Num(pct));
                    metrics.set(&k, o);
                }
                None => metrics.set(&k, Json::Num(v)),
            }
        }
        // Gated metrics the new document did not emit also survive: a
        // bench dropping a metric must be an explicit baseline edit, not
        // a silent un-gating by refresh.
        if let Some(old_metrics) = old
            .as_ref()
            .and_then(|b| b.get("benches"))
            .and_then(|bs| bs.get(&name))
            .and_then(Json::as_obj)
        {
            for (k, v) in old_metrics {
                if metrics.get(k).is_none() {
                    println!("note  {name}.{k}: not in new results — keeping its baseline entry");
                    metrics.set(k, v.clone());
                }
            }
        }
        benches.set(&name, metrics);
    }

    // Benches in the old baseline with no results in this run keep their
    // entries untouched: refreshing from a partial bench run must not
    // silently un-gate everything it did not re-measure.
    if let Some(old_benches) = old
        .as_ref()
        .and_then(|b| b.get("benches"))
        .and_then(Json::as_obj)
    {
        for (name, entry) in old_benches {
            if benches.get(name).is_none() {
                println!("note  {name}: no new results — keeping its existing baseline entry");
                benches.set(name, entry.clone());
            }
        }
    }

    let mut out = Json::obj();
    out.set("tolerance_pct", Json::Num(tolerance_pct));
    out.set("benches", benches);
    std::fs::write(baseline_path, format!("{out}\n"))
        .with_context(|| format!("writing {baseline_path:?}"))?;
    println!("baseline refreshed -> {baseline_path:?}");
    restamp_adjacent_manifest(baseline_path)
}

/// A refreshed baseline has new bytes; if a compact manifest next to it
/// (`MANIFEST.json`) checksums the baseline file, restamp that entry so
/// `mpai manifest verify` keeps passing without a manual re-stamp.
fn restamp_adjacent_manifest(baseline_path: &Path) -> Result<()> {
    let root = baseline_path.parent().unwrap_or_else(|| Path::new("."));
    let manifest_path = root.join("MANIFEST.json");
    if !manifest_path.exists() {
        return Ok(());
    }
    let rel = match baseline_path.file_name().and_then(|n| n.to_str()) {
        Some(n) => n.to_string(),
        None => return Ok(()),
    };
    let mut m = CompactManifest::load(&manifest_path)?;
    if !m.entries.contains_key(&rel) {
        return Ok(());
    }
    m.stamp_file(root, &rel)?;
    m.save(&manifest_path)?;
    println!("restamped {rel} in {manifest_path:?}");
    Ok(())
}

fn run() -> Result<usize> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.as_slice() {
        [cmd, baseline, results] if cmd == "check" => {
            check(Path::new(baseline), Path::new(results))
        }
        [cmd, baseline, results] if cmd == "refresh" => {
            refresh(Path::new(baseline), Path::new(results), false)?;
            Ok(0)
        }
        [cmd, flag, baseline, results] if cmd == "refresh" && flag == "--all" => {
            refresh(Path::new(baseline), Path::new(results), true)?;
            Ok(0)
        }
        _ => bail!(
            "usage: bench-gate check <baseline.json> <results-dir>\n\
             \x20      bench-gate refresh [--all] <baseline.json> <results-dir>\n\
             (results are the BENCH_*.json files benches emit under \
             MPAI_BENCH_JSON; refresh keeps unbaselined `null` metrics null \
             unless --all promotes them)"
        ),
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(0) => {
            println!("bench gate passed");
            ExitCode::SUCCESS
        }
        Ok(n) => {
            println!("bench gate FAILED: {n} regression(s)");
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direction_from_metric_name() {
        assert_eq!(direction("pool_fps"), Direction::HigherIsBetter);
        assert_eq!(direction("threaded_speedup"), Direction::HigherIsBetter);
        assert_eq!(direction("serve_loop_eps"), Direction::HigherIsBetter);
        assert_eq!(direction("serial_wall_s"), Direction::LowerIsBetter);
        assert_eq!(direction("latency_ms"), Direction::LowerIsBetter);
        assert_eq!(direction("occupancy"), Direction::TwoSided);
    }

    #[test]
    fn baseline_entry_forms() {
        let bare = Json::Num(2.5);
        assert_eq!(baseline_value(&bare), Some(2.5));
        assert_eq!(baseline_tolerance(&bare, 0.15), 0.15);

        let tracked = Json::Null;
        assert_eq!(baseline_value(&tracked), None);

        let mut obj = Json::obj();
        obj.set("value", Json::Num(1.5));
        obj.set("tolerance_pct", Json::Num(40.0));
        assert_eq!(baseline_value(&obj), Some(1.5));
        assert!((baseline_tolerance(&obj, 0.15) - 0.40).abs() < 1e-12);

        // Object without a value is tracked-only; without a tolerance it
        // inherits the file default.
        let mut bare_obj = Json::obj();
        bare_obj.set("tolerance_pct", Json::Num(40.0));
        assert_eq!(baseline_value(&bare_obj), None);
        let mut val_only = Json::obj();
        val_only.set("value", Json::Num(3.0));
        assert_eq!(baseline_tolerance(&val_only, 0.15), 0.15);
    }

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("bench_gate_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(dir.join("results")).unwrap();
        dir
    }

    #[test]
    fn refresh_preserves_tolerance_objects_and_null_tracking() {
        let dir = scratch("tol");
        let baseline = dir.join("baseline.json");
        std::fs::write(
            &baseline,
            r#"{"tolerance_pct": 15, "benches": {"plan_cache": {
                "cached_speedup": {"value": 10.0, "tolerance_pct": 40},
                "fresh_sweep_ms": null}}}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("results/BENCH_plan_cache.json"),
            r#"{"name": "plan_cache",
                "metrics": {"cached_speedup": 25.0, "fresh_sweep_ms": 3.2}}"#,
        )
        .unwrap();

        refresh(&baseline, &dir.join("results"), false).unwrap();

        let b = load(&baseline).unwrap();
        let bench = b.get("benches").and_then(|x| x.get("plan_cache")).unwrap();
        let sp = bench.get("cached_speedup").unwrap();
        // The gated value tracks the new observation; its per-metric
        // tolerance band survives the refresh.
        assert_eq!(sp.get("value").and_then(Json::as_f64), Some(25.0));
        assert_eq!(sp.get("tolerance_pct").and_then(Json::as_f64), Some(40.0));
        // Tracked-only metrics stay unbaselined.
        assert!(matches!(bench.get("fresh_sweep_ms"), Some(Json::Null)));

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn refresh_restamps_adjacent_compact_manifest() {
        let dir = scratch("stamp");
        let baseline = dir.join("baseline.json");
        std::fs::write(
            &baseline,
            r#"{"tolerance_pct": 15, "benches": {"plan_cache": {"cached_speedup": 10.0}}}"#,
        )
        .unwrap();
        std::fs::write(
            dir.join("results/BENCH_plan_cache.json"),
            r#"{"name": "plan_cache", "metrics": {"cached_speedup": 25.0}}"#,
        )
        .unwrap();
        let mut m = CompactManifest::new("bench");
        m.stamp_file(&dir, "baseline.json").unwrap();
        m.save(&dir.join("MANIFEST.json")).unwrap();
        let stale = m.entries["baseline.json"].sha256.clone();

        refresh(&baseline, &dir.join("results"), false).unwrap();

        // The refresh rewrote baseline.json *and* restamped its manifest
        // entry: the checksum round-trips against the new bytes.
        let m = CompactManifest::load(&dir.join("MANIFEST.json")).unwrap();
        assert_ne!(m.entries["baseline.json"].sha256, stale);
        assert_eq!(m.verify(&dir).unwrap(), 1);

        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn committed_bench_manifest_verifies_against_baseline() {
        // CI's manifest-verify step in executable form: the checked-in
        // bench/MANIFEST.json must checksum-match bench/baseline.json.
        let bench_dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench");
        let m = CompactManifest::load(&bench_dir.join("MANIFEST.json")).unwrap();
        assert!(m.entries.contains_key("baseline.json"));
        m.verify(&bench_dir).unwrap();
    }
}
