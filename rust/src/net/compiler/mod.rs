//! Graph compiler: deploy-time optimization passes and model partitioning
//! (the Vitis-AI / OpenVINO / TFLite toolflow substrate, DESIGN.md §4.2).

pub mod fusion;
pub mod partition;

pub use fusion::compile;
pub use partition::{
    enumerate_cuts, evaluate_cut, evaluate_partition, select_cut, Cut, Partition, SelectedCut,
    Stage,
};
