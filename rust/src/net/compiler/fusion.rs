//! Graph-optimizer passes — the "Vitis AI compiler" substrate (paper §II:
//! "the Vitis AI compiler ... performs optimizations (e.g., layer fusion)
//! in the network graph").
//!
//! * **BN folding**: BatchNorm following a Conv is absorbed into the conv's
//!   weights/bias at deploy time; the pass removes the BN node and rewires.
//! * **Activation fusion**: a standalone Activation whose producer is a
//!   Conv/Dense/Add with `Act::None` is folded into the producer.
//!
//! Passes are pure graph->graph functions, so they compose and are
//! property-tested (semantic accounting is preserved: MACs of removed nodes
//! are the elementwise ones the fused hardware executes for free).

use crate::net::graph::Graph;
use crate::net::layers::{Act, Layer, Op};

/// Fold BatchNorm nodes into their producing convolution.
///
/// BN nodes whose producer is not a conv (rare; none in the zoo) are kept.
pub fn fold_batchnorm(g: &Graph) -> Graph {
    let mut out = Graph::new(&g.name);
    // old id -> new id
    let mut remap: Vec<usize> = Vec::with_capacity(g.layers.len());

    for (idx, layer) in g.layers.iter().enumerate() {
        let is_foldable_bn = matches!(layer.op, Op::BatchNorm)
            && matches!(
                g.layers[layer.inputs[0]].op,
                Op::Conv { .. } | Op::Dense { .. }
            );
        if is_foldable_bn {
            // The BN output aliases its (already remapped) producer.
            let producer_new = remap[layer.inputs[0]];
            remap.push(producer_new);
            continue;
        }
        let new_inputs: Vec<usize> = layer.inputs.iter().map(|&i| remap[i]).collect();
        out.layers.push(Layer {
            name: layer.name.clone(),
            op: layer.op.clone(),
            inputs: new_inputs,
            out: layer.out,
        });
        remap.push(out.layers.len() - 1);
        let _ = idx;
    }
    out
}

/// Fuse standalone Activation nodes into an eligible producer.
pub fn fuse_activations(g: &Graph) -> Graph {
    let mut out = Graph::new(&g.name);
    let mut remap: Vec<usize> = Vec::with_capacity(g.layers.len());

    // Count consumers so we only fuse single-consumer producers.
    let mut consumers = vec![0usize; g.layers.len()];
    for l in &g.layers {
        for &i in &l.inputs {
            consumers[i] += 1;
        }
    }

    for layer in g.layers.iter() {
        if let Op::Activation(act) = &layer.op {
            let src = layer.inputs[0];
            if consumers[src] == 1 {
                let src_new = remap[src];
                let fused = match &mut out.layers[src_new].op {
                    Op::Conv { act: a, .. } | Op::Dense { act: a, .. } | Op::Add { act: a }
                        if *a == Act::None =>
                    {
                        *a = *act;
                        true
                    }
                    _ => false,
                };
                if fused {
                    remap.push(src_new);
                    continue;
                }
            }
        }
        let new_inputs: Vec<usize> = layer.inputs.iter().map(|&i| remap[i]).collect();
        out.layers.push(Layer {
            name: layer.name.clone(),
            op: layer.op.clone(),
            inputs: new_inputs,
            out: layer.out,
        });
        remap.push(out.layers.len() - 1);
    }
    out
}

/// The full deploy-compiler pipeline.
pub fn compile(g: &Graph) -> Graph {
    let folded = fold_batchnorm(g);
    fuse_activations(&folded)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::layers::Shape;
    use crate::net::models;

    #[test]
    fn folding_removes_all_zoo_bns() {
        for g in models::fig2_models() {
            let f = fold_batchnorm(&g);
            f.validate().unwrap();
            assert!(
                !f.layers.iter().any(|l| matches!(l.op, Op::BatchNorm)),
                "{} still has BN after folding",
                g.name
            );
        }
    }

    #[test]
    fn folding_preserves_conv_macs() {
        let g = models::resnet50::build(1000);
        let f = fold_batchnorm(&g);
        let conv_macs = |gr: &Graph| -> u64 {
            (0..gr.layers.len())
                .filter(|&i| matches!(gr.layers[i].op, Op::Conv { .. } | Op::Dense { .. }))
                .map(|i| gr.layers[i].macs(&gr.in_shapes(i)))
                .sum()
        };
        assert_eq!(conv_macs(&g), conv_macs(&f));
    }

    #[test]
    fn folding_preserves_outputs() {
        let g = models::mobilenet_v2::build(1000);
        let f = fold_batchnorm(&g);
        let out_names = |gr: &Graph| -> Vec<String> {
            gr.outputs()
                .iter()
                .map(|&i| gr.layers[i].name.clone())
                .collect()
        };
        assert_eq!(out_names(&g), out_names(&f));
    }

    #[test]
    fn activation_fusion_simple_chain() {
        let mut g = Graph::new("t");
        let x = g.input("in", Shape::new(8, 8, 3));
        let c = g.conv("c", x, 8, 3, 1, Act::None);
        g.add_act(c);
        let fused = fuse_activations(&g);
        fused.validate().unwrap();
        assert_eq!(fused.layers.len(), 2);
        match &fused.layers[1].op {
            Op::Conv { act, .. } => assert_eq!(*act, Act::Relu),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn activation_not_fused_into_multi_consumer() {
        let mut g = Graph::new("t");
        let x = g.input("in", Shape::new(8, 8, 3));
        let c = g.conv("c", x, 8, 3, 1, Act::None);
        let a = g.add("act", Op::Activation(Act::Relu), vec![c]);
        // Second consumer of the conv output.
        let c2 = g.conv("c2", c, 8, 3, 1, Act::None);
        let _ = g.addl("add", a, c2, Act::None);
        let fused = fuse_activations(&g);
        fused.validate().unwrap();
        assert!(fused
            .layers
            .iter()
            .any(|l| matches!(l.op, Op::Activation(_))));
    }

    #[test]
    fn compile_pipeline_validates_zoo() {
        for g in models::fig2_models() {
            compile(&g).validate().unwrap();
        }
    }

    // Test helper: append a standalone relu.
    impl Graph {
        fn add_act(&mut self, input: usize) -> usize {
            self.add("relu", Op::Activation(Act::Relu), vec![input])
        }
    }
}
