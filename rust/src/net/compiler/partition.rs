//! Model partitioning — the mechanism behind the paper's MPAI row and the
//! "methodology and design guidelines for the model partitioning" the paper
//! lists as future work (§IV); the cut-point sweep bench (AB-P) explores it.
//!
//! A [`Partition`] assigns every non-input layer to exactly one accelerator.
//! The canonical MPAI partition is a *topological 2-way cut*: prefix on the
//! fast INT8 engine, suffix on the FP16 engine; [`enumerate_cuts`] yields
//! every feasible cut with its cross-boundary transfer size.

use std::collections::BTreeMap;

use crate::net::graph::Graph;
use crate::net::layers::Op;

/// Assignment of layers to named accelerators.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    /// accelerator name per layer id; inputs get "" (unassigned).
    pub assign: Vec<String>,
}

#[derive(Debug)]
pub enum PartitionError {
    WrongArity { got: usize, want: usize },
    Unassigned(String),
    AssignedInput(String),
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::WrongArity { got, want } => {
                write!(f, "partition covers {got} layers but graph has {want}")
            }
            PartitionError::Unassigned(l) => write!(f, "layer {l} (non-input) is unassigned"),
            PartitionError::AssignedInput(l) => {
                write!(f, "input layer {l} must not be assigned")
            }
        }
    }
}

impl std::error::Error for PartitionError {}

impl Partition {
    /// Everything on one accelerator.
    pub fn single(g: &Graph, accel: &str) -> Partition {
        Partition {
            assign: g
                .layers
                .iter()
                .map(|l| {
                    if matches!(l.op, Op::Input) {
                        String::new()
                    } else {
                        accel.to_string()
                    }
                })
                .collect(),
        }
    }

    /// Topological 2-way cut: layers with id <= `cut` on `head_accel`
    /// (excluding inputs), the rest on `tail_accel`.
    pub fn two_way(g: &Graph, cut: usize, head_accel: &str, tail_accel: &str) -> Partition {
        Partition {
            assign: g
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| {
                    if matches!(l.op, Op::Input) {
                        String::new()
                    } else if i <= cut {
                        head_accel.to_string()
                    } else {
                        tail_accel.to_string()
                    }
                })
                .collect(),
        }
    }

    /// Assign by layer name (the manifest's backbone/head lists).
    pub fn by_names(g: &Graph, table: &BTreeMap<String, String>) -> Result<Partition, PartitionError> {
        let mut assign = Vec::with_capacity(g.layers.len());
        for l in &g.layers {
            if matches!(l.op, Op::Input) {
                assign.push(String::new());
            } else {
                match table.get(&l.name) {
                    Some(a) => assign.push(a.clone()),
                    None => return Err(PartitionError::Unassigned(l.name.clone())),
                }
            }
        }
        Ok(Partition { assign })
    }

    /// Validate the exactly-once covering invariant.
    pub fn validate(&self, g: &Graph) -> Result<(), PartitionError> {
        if self.assign.len() != g.layers.len() {
            return Err(PartitionError::WrongArity {
                got: self.assign.len(),
                want: g.layers.len(),
            });
        }
        for (l, a) in g.layers.iter().zip(&self.assign) {
            match (&l.op, a.is_empty()) {
                (Op::Input, false) => {
                    return Err(PartitionError::AssignedInput(l.name.clone()))
                }
                (Op::Input, true) => {}
                (_, true) => return Err(PartitionError::Unassigned(l.name.clone())),
                (_, false) => {}
            }
        }
        Ok(())
    }

    /// Distinct accelerators used, in first-appearance order.
    pub fn accelerators(&self) -> Vec<&str> {
        let mut seen = Vec::new();
        for a in &self.assign {
            if !a.is_empty() && !seen.contains(&a.as_str()) {
                seen.push(a.as_str());
            }
        }
        seen
    }

    /// Edges crossing accelerator boundaries: (producer id, consumer id,
    /// bytes at the given element width).
    pub fn cross_edges(&self, g: &Graph, elem_bytes: usize) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::new();
        for (ci, l) in g.layers.iter().enumerate() {
            for &pi in &l.inputs {
                let pa = &self.assign[pi];
                let ca = &self.assign[ci];
                // Input-layer tensors come from the host, not an accel.
                if pa.is_empty() || ca.is_empty() {
                    continue;
                }
                if pa != ca {
                    out.push((pi, ci, g.layers[pi].out.numel() * elem_bytes));
                }
            }
        }
        out
    }

    /// Total cross-boundary transfer bytes.
    pub fn transfer_bytes(&self, g: &Graph, elem_bytes: usize) -> usize {
        self.cross_edges(g, elem_bytes).iter().map(|e| e.2).sum()
    }
}

/// A candidate 2-way cut with its boundary size.
#[derive(Debug, Clone)]
pub struct Cut {
    /// Last layer id of the head segment.
    pub at: usize,
    pub layer_name: String,
    /// Tensor bytes crossing the boundary (at `elem_bytes` width).
    pub boundary_bytes: usize,
    /// MAC split: (head, tail).
    pub macs: (u64, u64),
}

/// Enumerate every topological 2-way cut (the MPAI design space).
pub fn enumerate_cuts(g: &Graph, elem_bytes: usize) -> Vec<Cut> {
    let total: u64 = g.total_macs();
    let mut head_macs = 0u64;
    let mut cuts = Vec::new();
    for i in 0..g.layers.len().saturating_sub(1) {
        head_macs += g.layers[i].macs(&g.in_shapes(i));
        // Boundary tensors: outputs of layers <= i consumed by layers > i.
        let mut bytes = 0usize;
        for (ci, l) in g.layers.iter().enumerate().skip(i + 1) {
            let _ = ci;
            for &pi in &l.inputs {
                if pi <= i && !matches!(g.layers[pi].op, Op::Input) {
                    bytes += g.layers[pi].out.numel() * elem_bytes;
                }
            }
        }
        if matches!(g.layers[i].op, Op::Input) {
            continue;
        }
        cuts.push(Cut {
            at: i,
            layer_name: g.layers[i].name.clone(),
            boundary_bytes: bytes,
            macs: (head_macs, total - head_macs),
        });
    }
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::models::ursonet;
    use crate::testkit::{check, Config};

    #[test]
    fn single_partition_validates() {
        let g = ursonet::build_lite();
        let p = Partition::single(&g, "dpu");
        p.validate(&g).unwrap();
        assert_eq!(p.accelerators(), vec!["dpu"]);
        assert!(p.cross_edges(&g, 1).is_empty());
    }

    #[test]
    fn two_way_cut_validates_and_crosses() {
        let g = ursonet::build_lite();
        let cut = g.layers.len() - 4; // before fc_bneck
        let p = Partition::two_way(&g, cut, "dpu", "vpu");
        p.validate(&g).unwrap();
        assert_eq!(p.accelerators(), vec!["dpu", "vpu"]);
        assert!(!p.cross_edges(&g, 1).is_empty());
    }

    #[test]
    fn mpai_cut_boundary_is_feature_map() {
        let g = ursonet::build_lite();
        // Cut after feat_pool (last backbone layer).
        let at = g
            .layers
            .iter()
            .position(|l| l.name == "feat_pool")
            .unwrap();
        let p = Partition::two_way(&g, at, "dpu", "vpu");
        // Boundary = 3*4*128 elements at 1 byte (INT8 transfer).
        assert_eq!(p.transfer_bytes(&g, 1), 3 * 4 * 128);
    }

    #[test]
    fn by_names_covers_or_errors() {
        let g = ursonet::build_lite();
        let mut table = BTreeMap::new();
        for n in ursonet::lite_backbone_layers() {
            table.insert(n.to_string(), "dpu".to_string());
        }
        // Missing heads -> error.
        assert!(Partition::by_names(&g, &table).is_err());
        for n in ursonet::lite_head_layers() {
            table.insert(n.to_string(), "vpu".to_string());
        }
        let p = Partition::by_names(&g, &table).unwrap();
        p.validate(&g).unwrap();
    }

    #[test]
    fn enumerate_cuts_macs_sum_to_total() {
        let g = ursonet::build_lite();
        let total = g.total_macs();
        for c in enumerate_cuts(&g, 1) {
            assert_eq!(c.macs.0 + c.macs.1, total, "cut at {}", c.layer_name);
        }
    }

    #[test]
    fn property_every_cut_validates_exactly_once() {
        // Coordinator invariant: any 2-way cut covers each non-input layer
        // exactly once and never assigns inputs.
        let g = ursonet::build_lite();
        check("cut_covering", Config::default(), move |ctx| {
            let cut = ctx.rng.below(g.layers.len());
            let p = Partition::two_way(&g, cut, "a", "b");
            p.validate(&g).map_err(|e| e.to_string())?;
            let assigned = p.assign.iter().filter(|a| !a.is_empty()).count();
            let non_input = g
                .layers
                .iter()
                .filter(|l| !matches!(l.op, Op::Input))
                .count();
            crate::prop_assert!(
                assigned == non_input,
                "assigned {assigned} != non-input {non_input}"
            );
            Ok(())
        });
    }

    #[test]
    fn property_transfer_bytes_monotone_in_elem_width() {
        let g = ursonet::build_lite();
        check("transfer_monotone", Config::default(), move |ctx| {
            let cut = ctx.rng.below(g.layers.len());
            let p = Partition::two_way(&g, cut, "a", "b");
            let b1 = p.transfer_bytes(&g, 1);
            let b2 = p.transfer_bytes(&g, 2);
            crate::prop_assert!(b2 == 2 * b1, "elem width scaling broken: {b1} {b2}");
            Ok(())
        });
    }

    #[test]
    fn random_name_tables_never_double_assign() {
        let g = ursonet::build_lite();
        check("by_names_exactly_once", Config::default(), move |ctx| {
            let accels = ["dpu", "vpu", "tpu", "cpu"];
            let mut table = BTreeMap::new();
            for l in &g.layers {
                if !matches!(l.op, Op::Input) {
                    table.insert(
                        l.name.clone(),
                        (*ctx.rng.choose(&accels)).to_string(),
                    );
                }
            }
            let p = Partition::by_names(&g, &table).map_err(|e| e.to_string())?;
            p.validate(&g).map_err(|e| e.to_string())?;
            Ok(())
        });
    }
}
