//! Model partitioning — the mechanism behind the paper's MPAI row and the
//! "methodology and design guidelines for the model partitioning" the paper
//! lists as future work (§IV); the cut-point sweep bench (AB-P) explores it.
//!
//! A [`Partition`] assigns every non-input layer to exactly one accelerator.
//! The canonical MPAI partition is a *topological 2-way cut*: prefix on the
//! fast INT8 engine, suffix on the FP16 engine; [`enumerate_cuts`] yields
//! every feasible cut with its cross-boundary transfer size,
//! [`Partition::n_way`] generalizes to N contiguous stages, and
//! [`select_cut`] sweeps the cut space under the analytic estimate model to
//! pick the steady-state-throughput-optimal feasible cut — the automatic
//! partitioning methodology §IV asks for.

use std::collections::BTreeMap;

use crate::accel::estimate::{partition_latency, PartitionLatency};
use crate::accel::interconnect::Link;
use crate::accel::traits::Accelerator;
use crate::coordinator::policy::Constraints;
use crate::net::graph::Graph;
use crate::net::layers::Op;

/// Assignment of layers to named accelerators.
#[derive(Debug, Clone, PartialEq)]
pub struct Partition {
    /// accelerator name per layer id; inputs get "" (unassigned).
    pub assign: Vec<String>,
}

#[derive(Debug)]
pub enum PartitionError {
    WrongArity { got: usize, want: usize },
    Unassigned(String),
    AssignedInput(String),
    BadCuts(String),
    NonContiguous(String),
}

impl std::fmt::Display for PartitionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionError::WrongArity { got, want } => {
                write!(f, "partition covers {got} layers but graph has {want}")
            }
            PartitionError::Unassigned(l) => write!(f, "layer {l} (non-input) is unassigned"),
            PartitionError::AssignedInput(l) => {
                write!(f, "input layer {l} must not be assigned")
            }
            PartitionError::BadCuts(msg) => write!(f, "bad cut list: {msg}"),
            PartitionError::NonContiguous(a) => write!(
                f,
                "accelerator {a} owns non-contiguous layer ranges (no linear pipeline order)"
            ),
        }
    }
}

impl std::error::Error for PartitionError {}

impl Partition {
    /// Everything on one accelerator.
    pub fn single(g: &Graph, accel: &str) -> Partition {
        Partition {
            assign: g
                .layers
                .iter()
                .map(|l| {
                    if matches!(l.op, Op::Input) {
                        String::new()
                    } else {
                        accel.to_string()
                    }
                })
                .collect(),
        }
    }

    /// Topological 2-way cut: layers with id <= `cut` on `head_accel`
    /// (excluding inputs), the rest on `tail_accel`.
    pub fn two_way(g: &Graph, cut: usize, head_accel: &str, tail_accel: &str) -> Partition {
        Partition {
            assign: g
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| {
                    if matches!(l.op, Op::Input) {
                        String::new()
                    } else if i <= cut {
                        head_accel.to_string()
                    } else {
                        tail_accel.to_string()
                    }
                })
                .collect(),
        }
    }

    /// N-way topological partition: `cuts[k]` is the last layer id of
    /// stage `k`; the final stage (`accels.len() - 1 == cuts.len()`) runs
    /// to the end of the graph.  Every stage must own at least one
    /// non-input layer.
    pub fn n_way(g: &Graph, cuts: &[usize], accels: &[&str]) -> Result<Partition, PartitionError> {
        if accels.len() != cuts.len() + 1 {
            return Err(PartitionError::BadCuts(format!(
                "{} stages need {} cuts, got {}",
                accels.len(),
                accels.len().saturating_sub(1),
                cuts.len()
            )));
        }
        if cuts.windows(2).any(|w| w[0] >= w[1]) {
            return Err(PartitionError::BadCuts(
                "cut ids must be strictly ascending".into(),
            ));
        }
        if let Some(&last) = cuts.last() {
            if last + 1 >= g.layers.len() {
                return Err(PartitionError::BadCuts(format!(
                    "cut at {last} leaves the final stage empty"
                )));
            }
        }
        let mut assign = Vec::with_capacity(g.layers.len());
        for (i, l) in g.layers.iter().enumerate() {
            if matches!(l.op, Op::Input) {
                assign.push(String::new());
            } else {
                let k = cuts.iter().position(|&c| i <= c).unwrap_or(cuts.len());
                assign.push(accels[k].to_string());
            }
        }
        let p = Partition { assign };
        p.validate(g)?;
        for (k, a) in accels.iter().enumerate() {
            let lo = if k == 0 { 0 } else { cuts[k - 1] + 1 };
            let hi = if k == cuts.len() {
                g.layers.len() - 1
            } else {
                cuts[k]
            };
            let any = (lo..=hi).any(|i| !matches!(g.layers[i].op, Op::Input));
            if !any {
                return Err(PartitionError::BadCuts(format!(
                    "stage {k} ({a}) owns no non-input layer"
                )));
            }
        }
        Ok(p)
    }

    /// Assign by layer name (the manifest's backbone/head lists).
    pub fn by_names(g: &Graph, table: &BTreeMap<String, String>) -> Result<Partition, PartitionError> {
        let mut assign = Vec::with_capacity(g.layers.len());
        for l in &g.layers {
            if matches!(l.op, Op::Input) {
                assign.push(String::new());
            } else {
                match table.get(&l.name) {
                    Some(a) => assign.push(a.clone()),
                    None => return Err(PartitionError::Unassigned(l.name.clone())),
                }
            }
        }
        Ok(Partition { assign })
    }

    /// Validate the exactly-once covering invariant.
    pub fn validate(&self, g: &Graph) -> Result<(), PartitionError> {
        if self.assign.len() != g.layers.len() {
            return Err(PartitionError::WrongArity {
                got: self.assign.len(),
                want: g.layers.len(),
            });
        }
        for (l, a) in g.layers.iter().zip(&self.assign) {
            match (&l.op, a.is_empty()) {
                (Op::Input, false) => {
                    return Err(PartitionError::AssignedInput(l.name.clone()))
                }
                (Op::Input, true) => {}
                (_, true) => return Err(PartitionError::Unassigned(l.name.clone())),
                (_, false) => {}
            }
        }
        Ok(())
    }

    /// Distinct accelerators used, in first-appearance order.
    pub fn accelerators(&self) -> Vec<&str> {
        let mut seen = Vec::new();
        for a in &self.assign {
            if !a.is_empty() && !seen.contains(&a.as_str()) {
                seen.push(a.as_str());
            }
        }
        seen
    }

    /// Edges crossing accelerator boundaries: (producer id, consumer id,
    /// bytes at the given element width).
    pub fn cross_edges(&self, g: &Graph, elem_bytes: usize) -> Vec<(usize, usize, usize)> {
        let mut out = Vec::new();
        for (ci, l) in g.layers.iter().enumerate() {
            for &pi in &l.inputs {
                let pa = &self.assign[pi];
                let ca = &self.assign[ci];
                // Input-layer tensors come from the host, not an accel.
                if pa.is_empty() || ca.is_empty() {
                    continue;
                }
                if pa != ca {
                    out.push((pi, ci, g.layers[pi].out.numel() * elem_bytes));
                }
            }
        }
        out
    }

    /// Total cross-boundary transfer bytes.
    pub fn transfer_bytes(&self, g: &Graph, elem_bytes: usize) -> usize {
        self.cross_edges(g, elem_bytes).iter().map(|e| e.2).sum()
    }

    /// Decompose into contiguous pipeline stages: maximal runs of
    /// consecutive layers on one accelerator, in topological order.
    /// Errors if an accelerator reappears after a different one — such a
    /// partition has no linear pipeline order.
    pub fn contiguous_stages(&self, g: &Graph) -> Result<Vec<Stage>, PartitionError> {
        self.validate(g)?;
        let mut stages: Vec<Stage> = Vec::new();
        for (i, a) in self.assign.iter().enumerate() {
            if a.is_empty() {
                continue;
            }
            match stages.last_mut() {
                Some(s) if &s.accel == a => s.layers.push(i),
                _ => {
                    if stages.iter().any(|s| &s.accel == a) {
                        return Err(PartitionError::NonContiguous(a.clone()));
                    }
                    stages.push(Stage {
                        accel: a.clone(),
                        layers: vec![i],
                    });
                }
            }
        }
        Ok(stages)
    }
}

/// One contiguous pipeline stage of a partition: an accelerator plus the
/// topological run of non-input layer ids it owns.
#[derive(Debug, Clone, PartialEq)]
pub struct Stage {
    pub accel: String,
    pub layers: Vec<usize>,
}

/// A candidate 2-way cut with its boundary size.
#[derive(Debug, Clone)]
pub struct Cut {
    /// Last layer id of the head segment.
    pub at: usize,
    pub layer_name: String,
    /// Tensor bytes crossing the boundary (at `elem_bytes` width).
    pub boundary_bytes: usize,
    /// MAC split: (head, tail).
    pub macs: (u64, u64),
}

/// Enumerate every topological 2-way cut (the MPAI design space).
pub fn enumerate_cuts(g: &Graph, elem_bytes: usize) -> Vec<Cut> {
    let total: u64 = g.total_macs();
    let mut head_macs = 0u64;
    let mut cuts = Vec::new();
    for i in 0..g.layers.len().saturating_sub(1) {
        head_macs += g.layers[i].macs(&g.in_shapes(i));
        // Boundary tensors: outputs of layers <= i consumed by layers > i.
        let mut bytes = 0usize;
        for (ci, l) in g.layers.iter().enumerate().skip(i + 1) {
            let _ = ci;
            for &pi in &l.inputs {
                if pi <= i && !matches!(g.layers[pi].op, Op::Input) {
                    bytes += g.layers[pi].out.numel() * elem_bytes;
                }
            }
        }
        if matches!(g.layers[i].op, Op::Input) {
            continue;
        }
        cuts.push(Cut {
            at: i,
            layer_name: g.layers[i].name.clone(),
            boundary_bytes: bytes,
            macs: (head_macs, total - head_macs),
        });
    }
    cuts
}

/// A cut chosen by [`select_cut`], with everything the pipeline builder
/// needs: the partition itself, its analytic latency breakdown, the
/// steady-state throughput that ranked it, and the two-engine energy.
#[derive(Debug, Clone)]
pub struct SelectedCut {
    pub cut: Cut,
    pub partition: Partition,
    pub latency: PartitionLatency,
    /// Steady-state pipelined throughput (the selection objective).
    pub steady_fps: f64,
    /// Modeled energy per frame summed over both engines (J).
    pub energy_j: f64,
}

/// Shared feasibility + scoring for any contiguous partition (used by
/// [`evaluate_cut`] and the pipeline planner's single-substrate
/// fallbacks): every assigned layer must be supported by its device, and
/// the analytic sequential latency / two-engine energy must satisfy
/// `Constraints::{max_total_ms, max_energy_j}`.  Accuracy bounds are
/// partition-invariant (they depend on the numerics pairing) and are
/// checked by the mode policy, not here.  Returns the analytic latency
/// and energy when feasible.
pub fn evaluate_partition(
    g: &Graph,
    partition: &Partition,
    accels: &BTreeMap<String, &dyn Accelerator>,
    link: &Link,
    constraints: &Constraints,
) -> Option<(PartitionLatency, f64)> {
    let supported = g.layers.iter().enumerate().all(|(i, l)| {
        matches!(l.op, Op::Input)
            || accels
                .get(&partition.assign[i])
                .is_some_and(|a| a.supports(l, &g.in_shapes(i)))
    });
    if !supported {
        return None;
    }
    let latency = partition_latency(g, partition, accels, link).ok()?;
    let total_s = latency.total_s();
    let energy_j: f64 = latency
        .segments
        .iter()
        .map(|(name, busy)| accels[name].power().energy_j(*busy, total_s))
        .sum();
    let over_ms = constraints
        .max_total_ms
        .is_some_and(|max| total_s * 1e3 > max);
    let over_j = constraints.max_energy_j.is_some_and(|max| energy_j > max);
    if over_ms || over_j {
        return None;
    }
    Some((latency, energy_j))
}

/// Evaluate one candidate cut under the analytic estimate model.
/// Returns `None` when the cut is infeasible (see [`evaluate_partition`]).
pub fn evaluate_cut(
    g: &Graph,
    cut: Cut,
    head: &dyn Accelerator,
    tail: &dyn Accelerator,
    link: &Link,
    constraints: &Constraints,
) -> Option<SelectedCut> {
    let mut accels: BTreeMap<String, &dyn Accelerator> = BTreeMap::new();
    accels.insert(head.name().to_string(), head);
    accels.insert(tail.name().to_string(), tail);

    let partition = Partition::two_way(g, cut.at, head.name(), tail.name());
    let (latency, energy_j) = evaluate_partition(g, &partition, &accels, link, constraints)?;
    let steady_fps = latency.pipelined_fps();
    Some(SelectedCut {
        cut,
        partition,
        latency,
        steady_fps,
        energy_j,
    })
}

/// Sweep every topological 2-way cut (head segment on `head`, tail on
/// `tail`, boundary carried by `link`) and return the feasible cut with
/// the highest steady-state pipelined throughput.  Ties break toward the
/// lower sequential latency, then the earlier cut, so selection is
/// deterministic.  Returns `None` when no cut is feasible (or the two
/// devices are the same engine — there is nothing to split).
///
/// The sweep is pure in its inputs, which is what lets
/// `coordinator::pipeline::plan_or_build` memoize its result in the
/// content-addressed plan cache: a cached plan is bit-identical to
/// re-running this sweep for the same (graph, constraints, pool, link)
/// request (DESIGN.md §4.10).
pub fn select_cut(
    g: &Graph,
    head: &dyn Accelerator,
    tail: &dyn Accelerator,
    link: &Link,
    constraints: &Constraints,
) -> Option<SelectedCut> {
    if head.name() == tail.name() {
        return None;
    }
    enumerate_cuts(g, 1)
        .into_iter()
        .filter_map(|c| evaluate_cut(g, c, head, tail, link, constraints))
        .fold(None, |best, cand| match best {
            None => Some(cand),
            Some(b) => {
                let better = cand.steady_fps > b.steady_fps
                    || (cand.steady_fps == b.steady_fps
                        && cand.latency.total_s() < b.latency.total_s());
                Some(if better { cand } else { b })
            }
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::interconnect::links;
    use crate::accel::{Cpu, Dpu, Tpu, Vpu};
    use crate::net::layers::{Act, Shape};
    use crate::net::models::ursonet;
    use crate::testkit::{check, Config};
    use crate::util::prng::Prng;

    #[test]
    fn single_partition_validates() {
        let g = ursonet::build_lite();
        let p = Partition::single(&g, "dpu");
        p.validate(&g).unwrap();
        assert_eq!(p.accelerators(), vec!["dpu"]);
        assert!(p.cross_edges(&g, 1).is_empty());
    }

    #[test]
    fn two_way_cut_validates_and_crosses() {
        let g = ursonet::build_lite();
        let cut = g.layers.len() - 4; // before fc_bneck
        let p = Partition::two_way(&g, cut, "dpu", "vpu");
        p.validate(&g).unwrap();
        assert_eq!(p.accelerators(), vec!["dpu", "vpu"]);
        assert!(!p.cross_edges(&g, 1).is_empty());
    }

    #[test]
    fn mpai_cut_boundary_is_feature_map() {
        let g = ursonet::build_lite();
        // Cut after feat_pool (last backbone layer).
        let at = g
            .layers
            .iter()
            .position(|l| l.name == "feat_pool")
            .unwrap();
        let p = Partition::two_way(&g, at, "dpu", "vpu");
        // Boundary = 3*4*128 elements at 1 byte (INT8 transfer).
        assert_eq!(p.transfer_bytes(&g, 1), 3 * 4 * 128);
    }

    #[test]
    fn by_names_covers_or_errors() {
        let g = ursonet::build_lite();
        let mut table = BTreeMap::new();
        for n in ursonet::lite_backbone_layers() {
            table.insert(n.to_string(), "dpu".to_string());
        }
        // Missing heads -> error.
        assert!(Partition::by_names(&g, &table).is_err());
        for n in ursonet::lite_head_layers() {
            table.insert(n.to_string(), "vpu".to_string());
        }
        let p = Partition::by_names(&g, &table).unwrap();
        p.validate(&g).unwrap();
    }

    #[test]
    fn enumerate_cuts_macs_sum_to_total() {
        let g = ursonet::build_lite();
        let total = g.total_macs();
        for c in enumerate_cuts(&g, 1) {
            assert_eq!(c.macs.0 + c.macs.1, total, "cut at {}", c.layer_name);
        }
    }

    #[test]
    fn property_every_cut_validates_exactly_once() {
        // Coordinator invariant: any 2-way cut covers each non-input layer
        // exactly once and never assigns inputs.
        let g = ursonet::build_lite();
        check("cut_covering", Config::default(), move |ctx| {
            let cut = ctx.rng.below(g.layers.len());
            let p = Partition::two_way(&g, cut, "a", "b");
            p.validate(&g).map_err(|e| e.to_string())?;
            let assigned = p.assign.iter().filter(|a| !a.is_empty()).count();
            let non_input = g
                .layers
                .iter()
                .filter(|l| !matches!(l.op, Op::Input))
                .count();
            crate::prop_assert!(
                assigned == non_input,
                "assigned {assigned} != non-input {non_input}"
            );
            Ok(())
        });
    }

    #[test]
    fn property_transfer_bytes_monotone_in_elem_width() {
        let g = ursonet::build_lite();
        check("transfer_monotone", Config::default(), move |ctx| {
            let cut = ctx.rng.below(g.layers.len());
            let p = Partition::two_way(&g, cut, "a", "b");
            let b1 = p.transfer_bytes(&g, 1);
            let b2 = p.transfer_bytes(&g, 2);
            crate::prop_assert!(b2 == 2 * b1, "elem width scaling broken: {b1} {b2}");
            Ok(())
        });
    }

    #[test]
    fn n_way_three_stages_cover_exactly_once() {
        let g = ursonet::build_lite();
        let c1 = g.layers.iter().position(|l| l.name == "s2_add").unwrap();
        let c2 = g.layers.iter().position(|l| l.name == "feat_pool").unwrap();
        let p = Partition::n_way(&g, &[c1, c2], &["dpu", "tpu", "vpu"]).unwrap();
        let stages = p.contiguous_stages(&g).unwrap();
        assert_eq!(stages.len(), 3);
        assert_eq!(stages[0].accel, "dpu");
        assert_eq!(stages[1].accel, "tpu");
        assert_eq!(stages[2].accel, "vpu");
        let covered: usize = stages.iter().map(|s| s.layers.len()).sum();
        let non_input = g
            .layers
            .iter()
            .filter(|l| !matches!(l.op, Op::Input))
            .count();
        assert_eq!(covered, non_input);
        // 3-way has two boundaries, both with traffic.
        assert!(p.cross_edges(&g, 1).len() >= 2);
    }

    #[test]
    fn n_way_rejects_bad_cut_lists() {
        let g = ursonet::build_lite();
        // Not ascending.
        assert!(Partition::n_way(&g, &[5, 3], &["a", "b", "c"]).is_err());
        // Arity mismatch.
        assert!(Partition::n_way(&g, &[3], &["a"]).is_err());
        // Final stage empty.
        assert!(Partition::n_way(&g, &[g.layers.len() - 1], &["a", "b"]).is_err());
        // First stage owns only the input layer.
        assert!(Partition::n_way(&g, &[0], &["a", "b"]).is_err());
    }

    #[test]
    fn non_contiguous_assignment_has_no_stages() {
        let g = ursonet::build_lite();
        let mut p = Partition::two_way(&g, 5, "a", "b");
        let last = g.layers.len() - 1;
        p.assign[last] = "a".into(); // a .. b .. a: no linear order
        assert!(matches!(
            p.contiguous_stages(&g),
            Err(PartitionError::NonContiguous(_))
        ));
    }

    #[test]
    fn two_way_stages_match_cut() {
        let g = ursonet::build_lite();
        let at = g.layers.iter().position(|l| l.name == "feat_pool").unwrap();
        let p = Partition::two_way(&g, at, "dpu", "vpu");
        let stages = p.contiguous_stages(&g).unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(*stages[0].layers.last().unwrap(), at);
        assert_eq!(stages[1].layers.first().copied(), Some(at + 1));
    }

    #[test]
    fn select_cut_deterministic_and_feasible() {
        let g = ursonet::build_lite();
        let (dpu, vpu) = (Dpu, Vpu);
        let c = Constraints::default();
        let a = select_cut(&g, &dpu, &vpu, &links::USB3, &c).unwrap();
        let b = select_cut(&g, &dpu, &vpu, &links::USB3, &c).unwrap();
        assert_eq!(a.cut.at, b.cut.at, "selection must be deterministic");
        assert!(a.steady_fps > 0.0 && a.energy_j > 0.0);
        // Impossible latency bound: nothing feasible.
        let tight = Constraints {
            max_total_ms: Some(1e-4),
            ..Default::default()
        };
        assert!(select_cut(&g, &dpu, &vpu, &links::USB3, &tight).is_none());
        // Same engine on both sides: nothing to split.
        assert!(select_cut(&g, &dpu, &dpu, &links::USB3, &c).is_none());
    }

    /// Random single-chain CNN: shapes stay valid under the builder's
    /// shape inference for any k/stride draw below.
    fn random_chain(rng: &mut Prng) -> Graph {
        let mut g = Graph::new("rand_chain");
        let x = g.input("in", Shape::new(32, 32, 3));
        let mut h = g.conv("c0", x, 8, 3, 1, Act::Relu);
        let n = 2 + rng.below(6);
        for i in 0..n {
            let c = 8 << rng.below(3);
            let stride = 1 + rng.below(2);
            let k = if rng.bool(0.5) { 1 } else { 3 };
            h = g.conv(&format!("c{}", i + 1), h, c, k, stride, Act::Relu);
        }
        let p = g.gap("gap", h);
        g.dense("fc", p, 10, Act::None);
        g
    }

    #[test]
    fn property_select_cut_is_throughput_argmax_and_feasible() {
        // ISSUE satellite: select_cut returns exactly the steady-throughput
        // argmax of enumerate_cuts under the analytic model, for random
        // graphs / device pairs / links / constraints, and never returns
        // an infeasible cut.
        check(
            "select_cut_argmax",
            Config {
                cases: 32,
                ..Config::default()
            },
            |ctx| {
                let g = random_chain(&mut ctx.rng);
                g.validate().map_err(|e| e.to_string())?;
                let devices: [Box<dyn Accelerator>; 4] = [
                    Box::new(Dpu),
                    Box::new(Vpu),
                    Box::new(Tpu),
                    Box::new(Cpu::zcu104()),
                ];
                let hi = ctx.rng.below(4);
                let ti = (hi + 1 + ctx.rng.below(3)) % 4;
                let head = devices[hi].as_ref();
                let tail = devices[ti].as_ref();
                let link = *ctx
                    .rng
                    .choose(&[links::USB3, links::USB2, links::AXI_HP, links::PCIE_X1]);

                // Sample a latency bound inside the unconstrained spread so
                // runs mix all-feasible, some-feasible, and none-feasible.
                let unconstrained: Vec<SelectedCut> = enumerate_cuts(&g, 1)
                    .into_iter()
                    .filter_map(|c| {
                        evaluate_cut(&g, c, head, tail, &link, &Constraints::default())
                    })
                    .collect();
                crate::prop_assert!(!unconstrained.is_empty(), "no cuts evaluated at all");
                let constraints = if ctx.rng.bool(0.4) {
                    Constraints::default()
                } else {
                    let lo = unconstrained
                        .iter()
                        .map(|s| s.latency.total_ms())
                        .fold(f64::INFINITY, f64::min);
                    let hi_ms = unconstrained
                        .iter()
                        .map(|s| s.latency.total_ms())
                        .fold(0.0, f64::max);
                    Constraints {
                        max_total_ms: Some(ctx.rng.range(lo * 0.5, hi_ms * 1.1)),
                        ..Default::default()
                    }
                };

                let feasible: Vec<SelectedCut> = enumerate_cuts(&g, 1)
                    .into_iter()
                    .filter_map(|c| evaluate_cut(&g, c, head, tail, &link, &constraints))
                    .collect();
                let sel = select_cut(&g, head, tail, &link, &constraints);
                match (feasible.is_empty(), sel) {
                    (true, None) => {}
                    (true, Some(s)) => {
                        return Err(format!(
                            "selected cut at {} but nothing is feasible",
                            s.cut.at
                        ))
                    }
                    (false, None) => {
                        return Err(format!(
                            "nothing selected but {} cuts are feasible",
                            feasible.len()
                        ))
                    }
                    (false, Some(s)) => {
                        let best_fps =
                            feasible.iter().map(|f| f.steady_fps).fold(0.0, f64::max);
                        crate::prop_assert!(
                            s.steady_fps >= best_fps,
                            "selected {} FPS < argmax {} FPS",
                            s.steady_fps,
                            best_fps
                        );
                        crate::prop_assert!(
                            feasible.iter().any(|f| f.cut.at == s.cut.at),
                            "selected cut {} is not in the feasible set",
                            s.cut.at
                        );
                        if let Some(max) = constraints.max_total_ms {
                            crate::prop_assert!(
                                s.latency.total_ms() <= max,
                                "selected cut violates max_total_ms"
                            );
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn random_name_tables_never_double_assign() {
        let g = ursonet::build_lite();
        check("by_names_exactly_once", Config::default(), move |ctx| {
            let accels = ["dpu", "vpu", "tpu", "cpu"];
            let mut table = BTreeMap::new();
            for l in &g.layers {
                if !matches!(l.op, Op::Input) {
                    table.insert(
                        l.name.clone(),
                        (*ctx.rng.choose(&accels)).to_string(),
                    );
                }
            }
            let p = Partition::by_names(&g, &table).map_err(|e| e.to_string())?;
            p.validate(&g).map_err(|e| e.to_string())?;
            Ok(())
        });
    }
}
