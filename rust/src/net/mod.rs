//! DNN graph IR, model zoo, and graph compiler (DESIGN.md §4.1–4.2).

pub mod compiler;
pub mod graph;
pub mod layers;
pub mod models;

pub use graph::Graph;
pub use layers::{Act, Layer, Op, PoolKind, Shape};
