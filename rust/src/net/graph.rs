//! DNN graph: topologically-ordered layer list with a builder API, shape
//! inference, validation, and whole-network accounting.

use std::collections::BTreeMap;

use crate::net::layers::{Act, Layer, Op, PoolKind, Shape};

/// A DNN as a DAG of layers in topological order (inputs precede users).
#[derive(Debug, Clone)]
pub struct Graph {
    pub name: String,
    pub layers: Vec<Layer>,
}

#[derive(Debug)]
pub enum GraphError {
    Invalid {
        graph: String,
        layer: String,
        msg: String,
    },
}

impl std::fmt::Display for GraphError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let GraphError::Invalid { graph, layer, msg } = self;
        write!(f, "graph {graph}: layer {layer}: {msg}")
    }
}

impl std::error::Error for GraphError {}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph {
            name: name.to_string(),
            layers: Vec::new(),
        }
    }

    fn err(&self, layer: &str, msg: String) -> GraphError {
        GraphError::Invalid {
            graph: self.name.clone(),
            layer: layer.to_string(),
            msg,
        }
    }

    // -- builder -------------------------------------------------------------

    pub fn input(&mut self, name: &str, shape: Shape) -> usize {
        self.layers.push(Layer {
            name: name.to_string(),
            op: Op::Input,
            inputs: vec![],
            out: shape,
        });
        self.layers.len() - 1
    }

    /// Push a layer, inferring its shape; panics on structural errors (the
    /// model zoo is static code — a bad definition should fail loudly).
    pub fn add(&mut self, name: &str, op: Op, inputs: Vec<usize>) -> usize {
        for &i in &inputs {
            assert!(
                i < self.layers.len(),
                "graph {}: layer {name}: input id {i} out of range",
                self.name
            );
        }
        let in_shapes: Vec<Shape> = inputs.iter().map(|&i| self.layers[i].out).collect();
        let out = Layer::infer_shape(&op, &in_shapes)
            .unwrap_or_else(|e| panic!("graph {}: layer {name}: {e}", self.name));
        self.layers.push(Layer {
            name: name.to_string(),
            op,
            inputs,
            out,
        });
        self.layers.len() - 1
    }

    // Convenience builders used heavily by the model zoo.

    pub fn conv(
        &mut self,
        name: &str,
        input: usize,
        cout: usize,
        k: usize,
        stride: usize,
        act: Act,
    ) -> usize {
        self.add(
            name,
            Op::Conv {
                kh: k,
                kw: k,
                stride,
                pad_h: k / 2,
                pad_w: k / 2,
                cout,
                groups: 1,
                act,
            },
            vec![input],
        )
    }

    pub fn dwconv(&mut self, name: &str, input: usize, k: usize, stride: usize, act: Act) -> usize {
        let c = self.layers[input].out.c;
        self.add(
            name,
            Op::Conv {
                kh: k,
                kw: k,
                stride,
                pad_h: k / 2,
                pad_w: k / 2,
                cout: c,
                groups: c,
                act,
            },
            vec![input],
        )
    }

    pub fn dense(&mut self, name: &str, input: usize, cout: usize, act: Act) -> usize {
        self.add(name, Op::Dense { cout, act }, vec![input])
    }

    pub fn maxpool(&mut self, name: &str, input: usize, k: usize, stride: usize) -> usize {
        self.add(
            name,
            Op::Pool {
                kind: PoolKind::Max,
                k,
                stride,
            },
            vec![input],
        )
    }

    pub fn avgpool(&mut self, name: &str, input: usize, k: usize, stride: usize) -> usize {
        self.add(
            name,
            Op::Pool {
                kind: PoolKind::Avg,
                k,
                stride,
            },
            vec![input],
        )
    }

    pub fn gap(&mut self, name: &str, input: usize) -> usize {
        self.add(name, Op::GlobalAvgPool, vec![input])
    }

    pub fn bn(&mut self, name: &str, input: usize) -> usize {
        self.add(name, Op::BatchNorm, vec![input])
    }

    pub fn addl(&mut self, name: &str, a: usize, b: usize, act: Act) -> usize {
        self.add(name, Op::Add { act }, vec![a, b])
    }

    pub fn concat(&mut self, name: &str, inputs: Vec<usize>) -> usize {
        self.add(name, Op::Concat, inputs)
    }

    // -- accessors / accounting ----------------------------------------------

    pub fn in_shapes(&self, idx: usize) -> Vec<Shape> {
        self.layers[idx]
            .inputs
            .iter()
            .map(|&i| self.layers[i].out)
            .collect()
    }

    /// Total multiply-accumulates per sample.
    pub fn total_macs(&self) -> u64 {
        (0..self.layers.len())
            .map(|i| self.layers[i].macs(&self.in_shapes(i)))
            .sum()
    }

    /// Total parameter count.
    pub fn total_params(&self) -> u64 {
        (0..self.layers.len())
            .map(|i| self.layers[i].params(&self.in_shapes(i)))
            .sum()
    }

    /// Largest single activation tensor in elements (on-chip buffer sizing).
    pub fn peak_activation(&self) -> usize {
        self.layers.iter().map(|l| l.out.numel()).max().unwrap_or(0)
    }

    /// Ids of layers nobody consumes (network outputs).
    pub fn outputs(&self) -> Vec<usize> {
        let mut consumed = vec![false; self.layers.len()];
        for l in &self.layers {
            for &i in &l.inputs {
                consumed[i] = true;
            }
        }
        (0..self.layers.len())
            .filter(|&i| !consumed[i] && !matches!(self.layers[i].op, Op::Input))
            .collect()
    }

    /// Validate structural invariants (tests + compiler entry).
    pub fn validate(&self) -> Result<(), GraphError> {
        let mut names = BTreeMap::new();
        for (i, l) in self.layers.iter().enumerate() {
            if let Some(prev) = names.insert(l.name.clone(), i) {
                return Err(self.err(
                    &l.name,
                    format!("duplicate layer name (first at index {prev})"),
                ));
            }
            for &inp in &l.inputs {
                if inp >= i {
                    return Err(self.err(&l.name, format!("input {inp} not before layer {i}")));
                }
            }
            let in_shapes = self.in_shapes(i);
            if !matches!(l.op, Op::Input) {
                let expect = Layer::infer_shape(&l.op, &in_shapes)
                    .map_err(|e| self.err(&l.name, e))?;
                if expect != l.out {
                    return Err(self.err(
                        &l.name,
                        format!("stored shape {:?} != inferred {:?}", l.out, expect),
                    ));
                }
            }
        }
        if self.layers.is_empty() {
            return Err(self.err("<graph>", "empty graph".into()));
        }
        Ok(())
    }

    /// One-line description used by the CLI `inspect` command.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} layers, {:.2} GMACs, {:.2} M params, outputs {:?}",
            self.name,
            self.layers.len(),
            self.total_macs() as f64 / 1e9,
            self.total_params() as f64 / 1e6,
            self.outputs()
                .iter()
                .map(|&i| self.layers[i].name.as_str())
                .collect::<Vec<_>>()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g.input("in", Shape::new(8, 8, 3));
        let c1 = g.conv("c1", x, 16, 3, 2, Act::Relu);
        let c2 = g.conv("c2", c1, 16, 3, 1, Act::None);
        let c3 = g.conv("c3", c1, 16, 3, 1, Act::None);
        let a = g.addl("add", c2, c3, Act::Relu);
        let p = g.gap("gap", a);
        g.dense("fc", p, 10, Act::None);
        g
    }

    #[test]
    fn builds_and_validates() {
        let g = tiny();
        g.validate().unwrap();
        assert_eq!(g.layers.len(), 7);
    }

    #[test]
    fn outputs_found() {
        let g = tiny();
        let outs = g.outputs();
        assert_eq!(outs.len(), 1);
        assert_eq!(g.layers[outs[0]].name, "fc");
    }

    #[test]
    fn accounting_positive_and_consistent() {
        let g = tiny();
        assert!(g.total_macs() > 0);
        assert!(g.total_params() > 0);
        assert_eq!(g.peak_activation(), 4 * 4 * 16); // 256 > input 8*8*3 = 192
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut g = Graph::new("dup");
        let x = g.input("in", Shape::new(4, 4, 3));
        g.conv("c", x, 8, 3, 1, Act::None);
        let y = g.conv("c2", x, 8, 3, 1, Act::None);
        g.layers[2].name = "c".into();
        let _ = y;
        assert!(g.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn forward_reference_panics() {
        let mut g = Graph::new("bad");
        let x = g.input("in", Shape::new(4, 4, 3));
        g.add(
            "c",
            Op::Conv {
                kh: 3,
                kw: 3,
                stride: 1,
                pad_h: 1,
                pad_w: 1,
                cout: 8,
                groups: 1,
                act: Act::None,
            },
            vec![x + 5],
        );
    }

    #[test]
    fn validate_catches_tampered_shape() {
        let mut g = tiny();
        g.layers[1].out = Shape::new(1, 1, 1);
        assert!(g.validate().is_err());
    }
}
