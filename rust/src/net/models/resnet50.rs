//! ResNet-50 (He et al., CVPR'16) at 224x224x3 — Fig. 2 "large" net, and the
//! backbone of full-size UrsoNet (Table I).
//!
//! Bottleneck stages [3, 4, 6, 3] with base widths 64/128/256/512 (x4
//! expansion).  Published accounting: 4.1 GMACs, 25.6 M params — asserted
//! within tolerance below.

use crate::net::graph::Graph;
use crate::net::layers::{Act, Shape};

fn conv_bn(g: &mut Graph, name: &str, x: usize, cout: usize, k: usize, s: usize, act: Act) -> usize {
    let c = g.conv(&format!("{name}_conv"), x, cout, k, s, act);
    g.bn(&format!("{name}_bn"), c)
}

/// Bottleneck residual block: 1x1 -> 3x3 -> 1x1(x4) with projection shortcut
/// on the first block of each stage.
fn bottleneck(g: &mut Graph, name: &str, x: usize, width: usize, stride: usize, project: bool) -> usize {
    let cout = width * 4;
    let a = conv_bn(g, &format!("{name}_a"), x, width, 1, stride, Act::Relu);
    let b = conv_bn(g, &format!("{name}_b"), a, width, 3, 1, Act::Relu);
    let c = conv_bn(g, &format!("{name}_c"), b, cout, 1, 1, Act::None);
    let short = if project {
        conv_bn(g, &format!("{name}_proj"), x, cout, 1, stride, Act::None)
    } else {
        x
    };
    g.addl(&format!("{name}_add"), short, c, Act::Relu)
}

/// Append the ResNet-50 backbone (stem through final 7x7(x2048) stage) to an
/// existing graph; returns the last feature node.  Shared by the classifier
/// build and the UrsoNet-full descriptor.
pub fn backbone(g: &mut Graph, x: usize) -> usize {
    let mut h = conv_bn(g, "stem", x, 64, 7, 2, Act::Relu);
    h = g.maxpool("stem_pool", h, 3, 2);
    let stages: [(usize, usize, usize); 4] =
        [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)];
    for (si, &(width, blocks, stride)) in stages.iter().enumerate() {
        for b in 0..blocks {
            let s = if b == 0 { stride } else { 1 };
            h = bottleneck(g, &format!("s{si}_b{b}"), h, width, s, b == 0);
        }
    }
    h
}

/// Build the ImageNet classifier.
pub fn build(classes: usize) -> Graph {
    let mut g = Graph::new("resnet50");
    let x = g.input("input", Shape::new(224, 224, 3));
    let h = backbone(&mut g, x);
    let p = g.gap("gap", h);
    g.dense("fc", p, classes, Act::Softmax);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates() {
        build(1000).validate().unwrap();
    }

    #[test]
    fn published_macs() {
        let gmacs = build(1000).total_macs() as f64 / 1e9;
        assert!((3.8..4.4).contains(&gmacs), "GMACs {gmacs}");
    }

    #[test]
    fn published_params() {
        let m = build(1000).total_params() as f64 / 1e6;
        assert!((25.0..26.5).contains(&m), "Mparams {m}");
    }

    #[test]
    fn final_feature_shape() {
        let g = build(1000);
        let gap_in = g.layers.iter().find(|l| l.name == "gap").unwrap();
        let src = gap_in.inputs[0];
        assert_eq!(g.layers[src].out, Shape::new(7, 7, 2048));
    }

    #[test]
    fn no_depthwise() {
        let g = build(1000);
        let dw = (0..g.layers.len())
            .filter(|&i| g.layers[i].is_depthwise(&g.in_shapes(i)))
            .count();
        assert_eq!(dw, 0);
    }
}
