//! Model zoo: exact layer dimensioning for the paper's evaluated networks.

pub mod inception_v4;
pub mod mobilenet_v2;
pub mod resnet50;
pub mod ursonet;

use crate::net::graph::Graph;

/// All Fig. 2 networks (ordered small -> large, as plotted).
pub fn fig2_models() -> Vec<Graph> {
    vec![
        mobilenet_v2::build(1000),
        resnet50::build(1000),
        inception_v4::build(1000),
    ]
}

/// Look a model up by CLI name (`"ursonet"` is an alias for the
/// paper-scale `ursonet_full`, matching the workload-spec vocabulary).
pub fn by_name(name: &str) -> Option<Graph> {
    match name {
        "mobilenet_v2" => Some(mobilenet_v2::build(1000)),
        "resnet50" => Some(resnet50::build(1000)),
        "inception_v4" => Some(inception_v4::build(1000)),
        "ursonet" | "ursonet_full" => Some(ursonet::build_full()),
        "ursonet_lite" => Some(ursonet::build_lite()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_all_validate() {
        for g in fig2_models() {
            g.validate().unwrap();
        }
    }

    #[test]
    fn by_name_round_trip() {
        for name in [
            "mobilenet_v2",
            "resnet50",
            "inception_v4",
            "ursonet_full",
            "ursonet_lite",
        ] {
            let g = by_name(name).unwrap();
            assert_eq!(g.name, name);
        }
        // The workload-spec alias resolves to the paper-scale network.
        assert_eq!(by_name("ursonet").unwrap().name, "ursonet_full");
        assert!(by_name("vgg16").is_none());
    }

    #[test]
    fn size_ordering_matches_fig2() {
        // Fig. 2 orders by complexity: MobileNetV2 < ResNet-50 < InceptionV4.
        let ms = fig2_models();
        assert!(ms[0].total_macs() < ms[1].total_macs());
        assert!(ms[1].total_macs() < ms[2].total_macs());
    }
}
