//! UrsoNet (Proença & Gao, ICRA'20) descriptors — the Table I workload.
//!
//! Two variants:
//!
//! * [`build_full`] — the paper-scale network: ResNet-50 backbone fed by the
//!   1280x960 camera path (UrsoNet reduces resolution before the backbone;
//!   we model the published configuration of a 512x384 backbone input —
//!   documented substitution, DESIGN.md §1 "Scaling note"), bottleneck FC,
//!   location head (3) and orientation soft-classification head.  This
//!   descriptor exists for the *analytic latency models*: Table I latencies
//!   are computed from it at paper scale.
//! * [`build_lite`] — the exact mirror of python/compile/ursonet.py
//!   (96x128x3 input, stages 16/32/64/128, flattened features, quaternion
//!   head).  This descriptor is what the coordinator partitions and
//!   schedules; its numerics come from the AOT artifacts.

use crate::net::graph::Graph;
use crate::net::layers::{Act, Shape};
use crate::net::models::resnet50;

/// Orientation soft-classification bins of full UrsoNet (default config).
pub const FULL_ORI_BINS: usize = 4096;

/// Backbone input of the full-size descriptor (see module docs).
pub const FULL_INPUT: Shape = Shape {
    h: 384,
    w: 512,
    c: 3,
};

/// Paper-scale UrsoNet: ResNet-50 backbone + pose heads.
pub fn build_full() -> Graph {
    let mut g = Graph::new("ursonet_full");
    let x = g.input("input", FULL_INPUT);
    let feat = resnet50::backbone(&mut g, x);
    let p = g.gap("gap", feat);
    let bneck = g.dense("fc_bneck", p, 1024, Act::Relu);
    g.dense("fc_loc", bneck, 3, Act::None);
    g.dense("fc_ori", bneck, FULL_ORI_BINS, Act::Softmax);
    g
}

/// UrsoNet-lite: the deployed testbed network (mirror of the L2 python
/// model; layer names match the python partition vocabulary).
pub fn build_lite() -> Graph {
    let mut g = Graph::new("ursonet_lite");
    let x = g.input("input", Shape::new(96, 128, 3));
    let mut h = g.conv("stem", x, 16, 3, 2, Act::Relu);
    let stages = [32usize, 64, 128];
    for (i, &c) in stages.iter().enumerate() {
        let si = i + 1;
        h = g.conv(&format!("s{si}_proj"), h, c, 3, 2, Act::Relu);
        let a = g.conv(&format!("s{si}_a"), h, c, 3, 1, Act::Relu);
        let b = g.conv(&format!("s{si}_b"), a, c, 3, 1, Act::None);
        h = g.addl(&format!("s{si}_add"), h, b, Act::Relu);
    }
    // 2x2 avg pool then flatten (implicit in Dense): fc_bneck consumes the
    // pooled 3x4x128 feature map, as in the python model.
    let h = g.avgpool("feat_pool", h, 2, 2);
    let bneck = g.dense("fc_bneck", h, 128, Act::Relu);
    g.dense("fc_loc", bneck, 3, Act::None);
    g.dense("fc_ori", bneck, 4, Act::None);
    g
}

/// Layer-name prefixes of the backbone (the DPU side of the MPAI cut).
pub fn lite_backbone_layers() -> Vec<&'static str> {
    vec![
        "stem", "s1_proj", "s1_a", "s1_b", "s1_add", "s2_proj", "s2_a", "s2_b", "s2_add",
        "s3_proj", "s3_a", "s3_b", "s3_add", "feat_pool",
    ]
}

/// Head layer names (the VPU side of the MPAI cut).
pub fn lite_head_layers() -> Vec<&'static str> {
    vec!["fc_bneck", "fc_loc", "fc_ori"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_validates() {
        build_full().validate().unwrap();
    }

    #[test]
    fn lite_validates() {
        build_lite().validate().unwrap();
    }

    #[test]
    fn full_macs_dominated_by_backbone() {
        let g = build_full();
        // ResNet-50 at 384x512 ≈ 4.1 GMACs x (384*512)/(224*224) ≈ 16 GMACs.
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((12.0..22.0).contains(&gmacs), "GMACs {gmacs}");
    }

    #[test]
    fn full_params_include_ori_head() {
        let g = build_full();
        let m = g.total_params() as f64 / 1e6;
        // 25.6 M backbone + 2048*1024 bneck + 1024*4096 ori ≈ 32 M.
        assert!((28.0..36.0).contains(&m), "Mparams {m}");
    }

    #[test]
    fn lite_matches_python_param_count() {
        // python: ursonet.param_count(init_params(0)) — pinned by
        // tests in python/tests/test_ursonet.py to (3e5, 2e6); the exact
        // value is asserted against the manifest in the integration tests.
        let g = build_lite();
        let p = g.total_params();
        assert!(p > 300_000 && p < 2_000_000, "params {p}");
    }

    #[test]
    fn lite_outputs_are_pose_heads() {
        let g = build_lite();
        let outs: Vec<&str> = g
            .outputs()
            .iter()
            .map(|&i| g.layers[i].name.as_str())
            .collect();
        assert_eq!(outs, vec!["fc_loc", "fc_ori"]);
    }

    #[test]
    fn lite_feature_map_shapes() {
        let g = build_lite();
        let add3 = g.layers.iter().find(|l| l.name == "s3_add").unwrap();
        assert_eq!(add3.out, Shape::new(6, 8, 128));
        let pool = g.layers.iter().find(|l| l.name == "feat_pool").unwrap();
        assert_eq!(pool.out, Shape::new(3, 4, 128));
    }

    #[test]
    fn backbone_plus_head_cover_graph() {
        let g = build_lite();
        let bb = lite_backbone_layers();
        let hd = lite_head_layers();
        let named: Vec<&str> = g
            .layers
            .iter()
            .filter(|l| !matches!(l.op, crate::net::layers::Op::Input))
            .map(|l| l.name.as_str())
            .collect();
        assert_eq!(named.len(), bb.len() + hd.len());
        for n in named {
            assert!(bb.contains(&n) || hd.contains(&n), "{n} unassigned");
        }
    }
}
