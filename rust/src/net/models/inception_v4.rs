//! Inception-V4 (Szegedy et al., AAAI'17) at 299x299x3 — Fig. 2 "largest" net.
//!
//! Full stem + 4x Inception-A + Reduction-A + 7x Inception-B + Reduction-B +
//! 3x Inception-C + GAP + FC-1000, with the published branch widths.
//! Published accounting: ~12.3 GMACs (24.6 GFLOPs), ~42.7 M params.
//!
//! Asymmetric convolutions (1x7/7x1, 1x3/3x1) carry their exact kernel
//! footprints via the IR's per-axis padding.

use crate::net::graph::Graph;
use crate::net::layers::{Act, Op, PoolKind, Shape};

/// conv + bn with explicit (kh, kw) and padding.
fn cb(
    g: &mut Graph,
    name: &str,
    x: usize,
    cout: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> usize {
    let c = g.add(
        &format!("{name}_conv"),
        Op::Conv {
            kh: k,
            kw: k,
            stride,
            pad_h: pad,
            pad_w: pad,
            cout,
            groups: 1,
            act: Act::Relu,
        },
        vec![x],
    );
    g.bn(&format!("{name}_bn"), c)
}

/// Square conv + bn, SAME padding.
fn cbs(g: &mut Graph, name: &str, x: usize, cout: usize, k: usize, stride: usize) -> usize {
    cb(g, name, x, cout, k, stride, k / 2)
}

/// "Valid" conv + bn (no padding).
fn cbv(g: &mut Graph, name: &str, x: usize, cout: usize, k: usize, stride: usize) -> usize {
    cb(g, name, x, cout, k, stride, 0)
}

/// Asymmetric conv + bn: exact (kh, kw) footprint with per-axis SAME pads.
fn cba(
    g: &mut Graph,
    name: &str,
    x: usize,
    cout: usize,
    kh: usize,
    kw: usize,
) -> usize {
    let c = g.add(
        &format!("{name}_conv"),
        Op::Conv {
            kh,
            kw,
            stride: 1,
            pad_h: kh / 2,
            pad_w: kw / 2,
            cout,
            groups: 1,
            act: Act::Relu,
        },
        vec![x],
    );
    g.bn(&format!("{name}_bn"), c)
}

/// Asymmetric pair: 1x1 reduce to `w1`, then 1xk -> kx1 (exact footprints,
/// as in the published Inception-B/C branches).
fn asym_pair(g: &mut Graph, name: &str, x: usize, w1: usize, w2: usize, k: usize) -> usize {
    let r = cbs(g, &format!("{name}_reduce"), x, w1, 1, 1);
    let mid = (w1 + w2) / 2;
    let a = cba(g, &format!("{name}_1x{k}"), r, mid, 1, k);
    cba(g, &format!("{name}_{k}x1"), a, w2, k, 1)
}

fn inception_a(g: &mut Graph, name: &str, x: usize) -> usize {
    let b0 = cbs(g, &format!("{name}_b0"), x, 96, 1, 1);
    let b1a = cbs(g, &format!("{name}_b1a"), x, 64, 1, 1);
    let b1b = cbs(g, &format!("{name}_b1b"), b1a, 96, 3, 1);
    let b2a = cbs(g, &format!("{name}_b2a"), x, 64, 1, 1);
    let b2b = cbs(g, &format!("{name}_b2b"), b2a, 96, 3, 1);
    let b2c = cbs(g, &format!("{name}_b2c"), b2b, 96, 3, 1);
    // 3x3/1 SAME avg-pool is shape-preserving in the real net; the IR pools
    // without padding, so use the k=1 shape-preserving stand-in (pooling
    // MACs are negligible at this granularity).
    let b3a = g.add(
        &format!("{name}_poolp"),
        Op::Pool {
            kind: PoolKind::Avg,
            k: 1,
            stride: 1,
        },
        vec![x],
    );
    let b3b = cbs(g, &format!("{name}_b3b"), b3a, 96, 1, 1);
    g.concat(&format!("{name}_cat"), vec![b0, b1b, b2c, b3b])
}

fn reduction_a(g: &mut Graph, name: &str, x: usize) -> usize {
    let b0 = cbv(g, &format!("{name}_b0"), x, 384, 3, 2);
    let b1a = cbs(g, &format!("{name}_b1a"), x, 192, 1, 1);
    let b1b = cbs(g, &format!("{name}_b1b"), b1a, 224, 3, 1);
    let b1c = cbv(g, &format!("{name}_b1c"), b1b, 256, 3, 2);
    let b2 = g.maxpool(&format!("{name}_pool"), x, 3, 2);
    g.concat(&format!("{name}_cat"), vec![b0, b1c, b2])
}

fn inception_b(g: &mut Graph, name: &str, x: usize) -> usize {
    let b0 = cbs(g, &format!("{name}_b0"), x, 384, 1, 1);
    let b1 = asym_pair(g, &format!("{name}_b1"), x, 192, 256, 7);
    let b2a = asym_pair(g, &format!("{name}_b2a"), x, 192, 224, 7);
    let b2b = cba(g, &format!("{name}_b2c"), b2a, 224, 7, 1);
    let b2 = cba(g, &format!("{name}_b2d"), b2b, 256, 1, 7);
    let b3a = g.add(
        &format!("{name}_poolp"),
        Op::Pool {
            kind: PoolKind::Avg,
            k: 1,
            stride: 1,
        },
        vec![x],
    );
    let b3 = cbs(g, &format!("{name}_b3"), b3a, 128, 1, 1);
    g.concat(&format!("{name}_cat"), vec![b0, b1, b2, b3])
}

fn reduction_b(g: &mut Graph, name: &str, x: usize) -> usize {
    let b0a = cbs(g, &format!("{name}_b0a"), x, 192, 1, 1);
    let b0b = cbv(g, &format!("{name}_b0b"), b0a, 192, 3, 2);
    let b1a = asym_pair(g, &format!("{name}_b1a"), x, 256, 320, 7);
    let b1b = cbv(g, &format!("{name}_b1b"), b1a, 320, 3, 2);
    let b2 = g.maxpool(&format!("{name}_pool"), x, 3, 2);
    g.concat(&format!("{name}_cat"), vec![b0b, b1b, b2])
}

fn inception_c(g: &mut Graph, name: &str, x: usize) -> usize {
    let b0 = cbs(g, &format!("{name}_b0"), x, 256, 1, 1);
    let b1 = asym_pair(g, &format!("{name}_b1"), x, 384, 512, 3);
    let b2a = asym_pair(g, &format!("{name}_b2a"), x, 384, 448, 3);
    let b2b = cba(g, &format!("{name}_b2c"), b2a, 512, 3, 1);
    let b2 = cba(g, &format!("{name}_b2d"), b2b, 512, 1, 3);
    let b3a = g.add(
        &format!("{name}_poolp"),
        Op::Pool {
            kind: PoolKind::Avg,
            k: 1,
            stride: 1,
        },
        vec![x],
    );
    let b3 = cbs(g, &format!("{name}_b3"), b3a, 256, 1, 1);
    g.concat(&format!("{name}_cat"), vec![b0, b1, b2, b3])
}

/// Build Inception-V4 for `classes` outputs.
pub fn build(classes: usize) -> Graph {
    let mut g = Graph::new("inception_v4");
    let x = g.input("input", Shape::new(299, 299, 3));

    // Stem.
    let mut h = cbv(&mut g, "stem1", x, 32, 3, 2); // 149x149x32
    h = cbv(&mut g, "stem2", h, 32, 3, 1); // 147x147x32
    h = cbs(&mut g, "stem3", h, 64, 3, 1); // 147x147x64
    let p1 = g.maxpool("stem_pool1", h, 3, 2); // 73x73x64
    let c1 = cbv(&mut g, "stem4", h, 96, 3, 2); // 73x73x96
    h = g.concat("stem_cat1", vec![p1, c1]); // 73x73x160
    let a1 = cbs(&mut g, "stem5a", h, 64, 1, 1);
    let a2 = cbv(&mut g, "stem5b", a1, 96, 3, 1); // 71x71x96
    let b1 = cbs(&mut g, "stem6a", h, 64, 1, 1);
    let b2a = cba(&mut g, "stem6b1", b1, 64, 1, 7);
    let b2 = cba(&mut g, "stem6b2", b2a, 64, 7, 1);
    let b3 = cbv(&mut g, "stem6c", b2, 96, 3, 1); // 71x71x96
    h = g.concat("stem_cat2", vec![a2, b3]); // 71x71x192
    let p2 = g.maxpool("stem_pool2", h, 3, 2); // 35x35x192
    let c2 = cbv(&mut g, "stem7", h, 192, 3, 2); // 35x35x192
    h = g.concat("stem_cat3", vec![p2, c2]); // 35x35x384

    for i in 0..4 {
        h = inception_a(&mut g, &format!("a{i}"), h);
    }
    h = reduction_a(&mut g, "ra", h); // 17x17x1024
    for i in 0..7 {
        h = inception_b(&mut g, &format!("b{i}"), h);
    }
    h = reduction_b(&mut g, "rb", h); // 8x8x1536
    for i in 0..3 {
        h = inception_c(&mut g, &format!("c{i}"), h);
    }
    let p = g.gap("gap", h);
    g.dense("fc", p, classes, Act::Softmax);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates() {
        build(1000).validate().unwrap();
    }

    #[test]
    fn macs_in_published_ballpark() {
        // Published ~12.3 GMACs (24.6 GFLOPs).
        let gmacs = build(1000).total_macs() as f64 / 1e9;
        assert!((10.5..14.0).contains(&gmacs), "GMACs {gmacs}");
    }

    #[test]
    fn params_in_published_ballpark() {
        // Published ~42.7 M.
        let m = build(1000).total_params() as f64 / 1e6;
        assert!((35.0..50.0).contains(&m), "Mparams {m}");
    }

    #[test]
    fn feature_grid_sizes() {
        let g = build(1000);
        let cat3 = g.layers.iter().find(|l| l.name == "stem_cat3").unwrap();
        assert_eq!(cat3.out, Shape::new(35, 35, 384));
        let ra = g.layers.iter().find(|l| l.name == "ra_cat").unwrap();
        assert_eq!(ra.out.h, 17);
        let rb = g.layers.iter().find(|l| l.name == "rb_cat").unwrap();
        assert_eq!(rb.out.h, 8);
    }

    #[test]
    fn much_bigger_than_resnet50() {
        use crate::net::models::resnet50;
        let iv4 = build(1000);
        let r50 = resnet50::build(1000);
        assert!(iv4.total_macs() > r50.total_macs());
        assert!(iv4.total_params() > r50.total_params());
    }
}
