//! MobileNetV2 (Sandler et al., CVPR'18) at 224x224x3 — Fig. 2 "small" net.
//!
//! Exact inverted-residual dimensioning: (expansion t, channels c, repeats n,
//! stride s) = (1,16,1,1) (6,24,2,2) (6,32,3,2) (6,64,4,2) (6,96,3,1)
//! (6,160,3,2) (6,320,1,1), 1x1 head to 1280, GAP, FC-1000.
//! BatchNorm follows every conv (folded by the graph compiler).
//!
//! Accounting cross-check (tests below): ~0.32 GMACs, ~3.5 M params — the
//! published figures (300 MMACs / 3.4 M) within rounding of the BN params.

use crate::net::graph::Graph;
use crate::net::layers::{Act, Shape};

fn conv_bn(g: &mut Graph, name: &str, x: usize, cout: usize, k: usize, s: usize, act: Act) -> usize {
    let c = g.conv(&format!("{name}_conv"), x, cout, k, s, act);
    g.bn(&format!("{name}_bn"), c)
}

fn dw_bn(g: &mut Graph, name: &str, x: usize, k: usize, s: usize, act: Act) -> usize {
    let c = g.dwconv(&format!("{name}_dw"), x, k, s, act);
    g.bn(&format!("{name}_bn"), c)
}

/// One inverted residual block.
fn inverted_residual(g: &mut Graph, name: &str, x: usize, t: usize, cout: usize, s: usize) -> usize {
    let cin = g.layers[x].out.c;
    let mut h = x;
    if t != 1 {
        h = conv_bn(g, &format!("{name}_expand"), h, cin * t, 1, 1, Act::Relu6);
    }
    h = dw_bn(g, &format!("{name}_dwise"), h, 3, s, Act::Relu6);
    h = conv_bn(g, &format!("{name}_project"), h, cout, 1, 1, Act::None);
    if s == 1 && cin == cout {
        h = g.addl(&format!("{name}_add"), x, h, Act::None);
    }
    h
}

/// Build MobileNetV2-1.0 for `classes` outputs.
pub fn build(classes: usize) -> Graph {
    let mut g = Graph::new("mobilenet_v2");
    let x = g.input("input", Shape::new(224, 224, 3));
    let mut h = conv_bn(&mut g, "stem", x, 32, 3, 2, Act::Relu6);

    let spec: [(usize, usize, usize, usize); 7] = [
        (1, 16, 1, 1),
        (6, 24, 2, 2),
        (6, 32, 3, 2),
        (6, 64, 4, 2),
        (6, 96, 3, 1),
        (6, 160, 3, 2),
        (6, 320, 1, 1),
    ];
    for (bi, &(t, c, n, s)) in spec.iter().enumerate() {
        for i in 0..n {
            let stride = if i == 0 { s } else { 1 };
            h = inverted_residual(&mut g, &format!("block{bi}_{i}"), h, t, c, stride);
        }
    }
    h = conv_bn(&mut g, "head", h, 1280, 1, 1, Act::Relu6);
    let p = g.gap("gap", h);
    g.dense("fc", p, classes, Act::Softmax);
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates() {
        build(1000).validate().unwrap();
    }

    #[test]
    fn published_macs() {
        let g = build(1000);
        let gmacs = g.total_macs() as f64 / 1e9;
        assert!((0.28..0.40).contains(&gmacs), "GMACs {gmacs}");
    }

    #[test]
    fn published_params() {
        let g = build(1000);
        let m = g.total_params() as f64 / 1e6;
        assert!((3.2..3.8).contains(&m), "Mparams {m}");
    }

    #[test]
    fn final_spatial_is_7x7() {
        let g = build(1000);
        // Find the last conv before gap: head_bn output must be 7x7x1280.
        let head = g.layers.iter().find(|l| l.name == "head_bn").unwrap();
        assert_eq!(head.out, Shape::new(7, 7, 1280));
    }

    #[test]
    fn is_depthwise_heavy() {
        // >30% of layers are depthwise — the property that tanks VPU
        // utilization in Fig. 2 (DESIGN.md §1).
        let g = build(1000);
        let dw = (0..g.layers.len())
            .filter(|&i| g.layers[i].is_depthwise(&g.in_shapes(i)))
            .count();
        assert!(dw >= 17, "depthwise count {dw}");
    }
}
