//! Layer vocabulary of the DNN graph IR.
//!
//! Shapes are per-sample (H, W, C); the analytic accelerator models multiply
//! by batch where relevant.  `macs()`/`params()`/`output_bytes()` are the
//! accounting primitives every timing model consumes.

/// Spatial/feature shape of one tensor (batch excluded).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Shape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Shape {
    pub fn new(h: usize, w: usize, c: usize) -> Shape {
        Shape { h, w, c }
    }

    /// Feature vector (1x1xC).
    pub fn vec(c: usize) -> Shape {
        Shape { h: 1, w: 1, c }
    }

    pub fn numel(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// Activation functions (fused into the producing layer by the compiler).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Relu,
    Relu6,
    Softmax,
    None,
}

/// Pooling flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    Max,
    Avg,
}

/// Layer operator kinds.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// Network input placeholder.
    Input,
    /// 2-D convolution. `groups == cin` expresses depthwise.
    Conv {
        kh: usize,
        kw: usize,
        stride: usize,
        /// Padding on top/bottom (rows).
        pad_h: usize,
        /// Padding on left/right (cols).
        pad_w: usize,
        cout: usize,
        groups: usize,
        act: Act,
    },
    /// Fully connected (flattens input).
    Dense { cout: usize, act: Act },
    /// Window pooling.
    Pool {
        kind: PoolKind,
        k: usize,
        stride: usize,
    },
    /// Global average pool -> 1x1xC.
    GlobalAvgPool,
    /// Batch normalization (folded into the preceding conv by the compiler).
    BatchNorm,
    /// Elementwise residual add of exactly two inputs.
    Add { act: Act },
    /// Channel concatenation of >= 2 inputs (Inception blocks).
    Concat,
    /// Standalone activation (when not fused).
    Activation(Act),
}

/// A node of the graph: operator + input node ids + inferred output shape.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<usize>,
    pub out: Shape,
}

impl Layer {
    /// Multiply-accumulate count per sample.
    pub fn macs(&self, in_shapes: &[Shape]) -> u64 {
        match &self.op {
            Op::Conv {
                kh,
                kw,
                cout,
                groups,
                ..
            } => {
                let cin = in_shapes[0].c;
                let per_out = kh * kw * cin / groups;
                (self.out.h * self.out.w * cout * per_out) as u64
            }
            Op::Dense { cout, .. } => (in_shapes[0].numel() * cout) as u64,
            // Pool/add/bn/act are measured as "effective MACs" ~ elementwise
            // ops / 2 so vector-unit time is charged consistently.
            Op::Pool { k, .. } => (self.out.numel() * k * k / 2) as u64,
            Op::GlobalAvgPool => (in_shapes[0].numel() / 2) as u64,
            Op::BatchNorm => in_shapes[0].numel() as u64,
            Op::Add { .. } => (self.out.numel() / 2) as u64,
            Op::Activation(_) => (self.out.numel() / 2) as u64,
            Op::Concat | Op::Input => 0,
        }
    }

    /// Parameter count (weights + bias).
    pub fn params(&self, in_shapes: &[Shape]) -> u64 {
        match &self.op {
            Op::Conv {
                kh,
                kw,
                cout,
                groups,
                ..
            } => {
                let cin = in_shapes[0].c;
                (kh * kw * (cin / groups) * cout + cout) as u64
            }
            Op::Dense { cout, .. } => (in_shapes[0].numel() * cout + cout) as u64,
            Op::BatchNorm => (2 * in_shapes[0].c) as u64,
            _ => 0,
        }
    }

    /// Whether this is a depthwise conv (groups == cin) — the op class with
    /// collapsed MAC-array utilization on every modeled accelerator.
    pub fn is_depthwise(&self, in_shapes: &[Shape]) -> bool {
        matches!(&self.op, Op::Conv { groups, .. } if *groups == in_shapes[0].c && *groups > 1)
    }

    /// Infer output shape from input shapes (panics on arity mismatch —
    /// graph construction validates arity before calling).
    pub fn infer_shape(op: &Op, in_shapes: &[Shape]) -> Result<Shape, String> {
        match op {
            Op::Input => Err("input shape must be given explicitly".into()),
            Op::Conv {
                kh,
                kw,
                stride,
                pad_h,
                pad_w,
                cout,
                groups,
                ..
            } => {
                let s = in_shapes[0];
                if s.c % groups != 0 {
                    return Err(format!("conv groups {groups} does not divide cin {}", s.c));
                }
                if cout % groups != 0 {
                    return Err(format!("conv groups {groups} does not divide cout {cout}"));
                }
                if s.h + 2 * pad_h < *kh || s.w + 2 * pad_w < *kw {
                    return Err(format!("conv kernel {kh}x{kw} larger than padded input"));
                }
                Ok(Shape::new(
                    (s.h + 2 * pad_h - kh) / stride + 1,
                    (s.w + 2 * pad_w - kw) / stride + 1,
                    *cout,
                ))
            }
            Op::Dense { cout, .. } => Ok(Shape::vec(*cout)),
            Op::Pool { k, stride, .. } => {
                let s = in_shapes[0];
                if s.h < *k || s.w < *k {
                    return Err(format!("pool window {k} larger than input {}x{}", s.h, s.w));
                }
                Ok(Shape::new((s.h - k) / stride + 1, (s.w - k) / stride + 1, s.c))
            }
            Op::GlobalAvgPool => Ok(Shape::vec(in_shapes[0].c)),
            Op::BatchNorm | Op::Activation(_) => Ok(in_shapes[0]),
            Op::Add { .. } => {
                if in_shapes.len() != 2 {
                    return Err("add needs exactly 2 inputs".into());
                }
                if in_shapes[0] != in_shapes[1] {
                    return Err(format!(
                        "add shape mismatch {:?} vs {:?}",
                        in_shapes[0], in_shapes[1]
                    ));
                }
                Ok(in_shapes[0])
            }
            Op::Concat => {
                if in_shapes.len() < 2 {
                    return Err("concat needs >= 2 inputs".into());
                }
                let (h, w) = (in_shapes[0].h, in_shapes[0].w);
                let mut c = 0;
                for s in in_shapes {
                    if s.h != h || s.w != w {
                        return Err(format!("concat spatial mismatch {s:?}"));
                    }
                    c += s.c;
                }
                Ok(Shape::new(h, w, c))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn conv(k: usize, stride: usize, pad: usize, cout: usize, groups: usize) -> Op {
        Op::Conv {
            kh: k,
            kw: k,
            stride,
            pad_h: pad,
            pad_w: pad,
            cout,
            groups,
            act: Act::Relu,
        }
    }

    #[test]
    fn conv_shape_same_padding() {
        let s = Layer::infer_shape(&conv(3, 1, 1, 64, 1), &[Shape::new(56, 56, 32)]).unwrap();
        assert_eq!(s, Shape::new(56, 56, 64));
    }

    #[test]
    fn conv_shape_stride2() {
        let s = Layer::infer_shape(&conv(3, 2, 1, 64, 1), &[Shape::new(224, 224, 3)]).unwrap();
        assert_eq!(s, Shape::new(112, 112, 64));
    }

    #[test]
    fn conv_rejects_bad_groups() {
        assert!(Layer::infer_shape(&conv(3, 1, 1, 64, 5), &[Shape::new(8, 8, 32)]).is_err());
    }

    #[test]
    fn conv_macs_known() {
        // 3x3x16->32 at 8x8 output: 8*8*32*3*3*16 = 294912.
        let l = Layer {
            name: "c".into(),
            op: conv(3, 1, 1, 32, 1),
            inputs: vec![0],
            out: Shape::new(8, 8, 32),
        };
        assert_eq!(l.macs(&[Shape::new(8, 8, 16)]), 294_912);
    }

    #[test]
    fn depthwise_macs_divide_by_groups() {
        let l = Layer {
            name: "dw".into(),
            op: conv(3, 1, 1, 32, 32),
            inputs: vec![0],
            out: Shape::new(8, 8, 32),
        };
        // 8*8*32*3*3*1 = 18432.
        assert_eq!(l.macs(&[Shape::new(8, 8, 32)]), 18_432);
        assert!(l.is_depthwise(&[Shape::new(8, 8, 32)]));
    }

    #[test]
    fn dense_params_include_bias() {
        let l = Layer {
            name: "fc".into(),
            op: Op::Dense {
                cout: 10,
                act: Act::None,
            },
            inputs: vec![0],
            out: Shape::vec(10),
        };
        assert_eq!(l.params(&[Shape::vec(128)]), 128 * 10 + 10);
    }

    #[test]
    fn add_requires_matching_shapes() {
        let a = Shape::new(8, 8, 16);
        let b = Shape::new(8, 8, 32);
        assert!(Layer::infer_shape(&Op::Add { act: Act::None }, &[a, b]).is_err());
        assert_eq!(
            Layer::infer_shape(&Op::Add { act: Act::None }, &[a, a]).unwrap(),
            a
        );
    }

    #[test]
    fn concat_sums_channels() {
        let s = Layer::infer_shape(
            &Op::Concat,
            &[Shape::new(8, 8, 16), Shape::new(8, 8, 32), Shape::new(8, 8, 8)],
        )
        .unwrap();
        assert_eq!(s, Shape::new(8, 8, 56));
    }

    #[test]
    fn global_pool_to_vector() {
        let s = Layer::infer_shape(&Op::GlobalAvgPool, &[Shape::new(7, 7, 2048)]).unwrap();
        assert_eq!(s, Shape::vec(2048));
    }
}
