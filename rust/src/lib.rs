//! # MPAI — MPSoC + AI-accelerator co-processing for vision in space
//!
//! Full-system reproduction of *"MPAI: A Co-Processing Architecture with
//! MPSoC & AI Accelerators for Vision Applications in Space"* (Leon,
//! Minaidis, Soudris, Lentaris — IEEE ICECS 2024).
//!
//! The crate is the L3 (Rust) layer of a three-layer stack:
//!
//! * **L1/L2 (build-time python)**: Pallas kernels + JAX UrsoNet-lite are
//!   AOT-lowered to HLO-text artifacts (`make artifacts`); python never
//!   runs at request time.
//! * **L3 (this crate)**: the MPAI coordinator — sensor ingest, deadline-
//!   bounded batching, policy-routed multi-backend dispatch with failover
//!   across accelerator substrates, PJRT execution of the quantized
//!   artifacts, telemetry — plus every substrate the paper's testbed
//!   provides in hardware (accelerator timing/power models, DNN graph IR +
//!   zoo + compiler, pose toolkit).
//!
//! See DESIGN.md (repo root) for the system inventory and EXPERIMENTS.md
//! for the paper-vs-measured record.

pub mod accel;
pub mod coordinator;
pub mod net;
pub mod pose;
pub mod runtime;
pub mod sensor;
pub mod testkit;
pub mod util;
